//! TensorRT-like engine planning with GPU fallback.
//!
//! Given a graph whose execution is requested on the DLA, walk the layers
//! in topological order, group maximal runs of DLA-supported layers into
//! DLA subgraphs and unsupported runs into GPU fallback subgraphs, and
//! account for every DLA↔GPU transition. This is the mechanism behind all
//! of the paper's fallback observations (Figs 9–12) and the subgraph-limit
//! failure mode (§II.C).

use super::rules::{check_layer, DlaVersion, Verdict};
use crate::error::{Error, Result};
use crate::graph::{Graph, NodeId};
use crate::hw::EngineKind;

/// A maximal run of consecutive compute layers on one engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub engine: EngineKind,
    /// Node ids (graph topological order).
    pub nodes: Vec<NodeId>,
}

/// The result of planning a graph for DLA-primary execution.
#[derive(Debug, Clone)]
pub struct EnginePlan {
    pub segments: Vec<Segment>,
    /// Number of DLA subgraphs (TensorRT loadable count).
    pub dla_subgraphs: usize,
    /// Number of DLA↔GPU transitions (each pays a reformat).
    pub transitions: usize,
    /// Per-fallback-layer reasons, for diagnostics.
    pub fallback_reasons: Vec<(NodeId, String)>,
}

impl EnginePlan {
    /// True when the whole model lives on the DLA (the goal of the
    /// paper's surgery).
    pub fn fully_dla_resident(&self) -> bool {
        self.segments.iter().all(|s| s.engine == EngineKind::Dla)
    }

    /// Structured fallback diagnostics: `(node id, layer name, reason)`
    /// per GPU-fallback layer, resolved against the planned graph. This
    /// is the machine-readable form of [`Self::fallback_reasons`] —
    /// consumed by `report pipeline`'s `dla_plans` section and by the
    /// auto-placement planner's rejection output, not just pretty-printed
    /// by `check-dla`.
    pub fn fallback_details(&self, graph: &Graph) -> Vec<(NodeId, String, String)> {
        self.fallback_reasons
            .iter()
            .map(|(id, reason)| (*id, graph.node(*id).name.clone(), reason.clone()))
            .collect()
    }

    /// Fraction of compute layers on the GPU.
    pub fn gpu_layer_fraction(&self) -> f64 {
        let total: usize = self.segments.iter().map(|s| s.nodes.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let gpu: usize = self
            .segments
            .iter()
            .filter(|s| s.engine == EngineKind::Gpu)
            .map(|s| s.nodes.len())
            .sum();
        gpu as f64 / total as f64
    }
}

/// Merge small DLA-compatible islands into adjacent GPU fallback runs —
/// the TensorRT `min subgraph size` behaviour: a couple of cheap pointwise
/// layers between two fallback layers are not worth two extra engine
/// transitions. `flags[i]` is true when layer `i` is DLA-supported;
/// returns the effective engine per layer.
pub fn assign_engines(flags: &[bool], min_island: usize) -> Vec<EngineKind> {
    let n = flags.len();
    let mut engines: Vec<EngineKind> = flags
        .iter()
        .map(|&ok| if ok { EngineKind::Dla } else { EngineKind::Gpu })
        .collect();
    if min_island <= 1 || !flags.iter().any(|&f| !f) {
        return engines;
    }
    // Find DLA runs and demote short ones adjacent to GPU runs.
    let mut i = 0;
    while i < n {
        if engines[i] == EngineKind::Dla {
            let start = i;
            while i < n && engines[i] == EngineKind::Dla {
                i += 1;
            }
            let len = i - start;
            let gpu_left = start > 0; // predecessor run is GPU
            let gpu_right = i < n;
            if len < min_island && (gpu_left || gpu_right) {
                for e in engines[start..i].iter_mut() {
                    *e = EngineKind::Gpu;
                }
            }
        } else {
            i += 1;
        }
    }
    engines
}

/// Plan DLA-primary execution of `graph`.
///
/// `max_subgraphs` mirrors the TensorRT per-core loadable limit; planning
/// fails (as the real engine build does) when exceeded. `min_island` is
/// the minimum DLA subgraph size (1 = pure per-layer verdicts).
pub fn plan_with_island(
    graph: &Graph,
    version: DlaVersion,
    max_subgraphs: usize,
    min_island: usize,
) -> Result<EnginePlan> {
    let layers = graph.compute_layers();
    let mut reasons = Vec::new();
    let flags: Vec<bool> = layers
        .iter()
        .map(|&id| {
            let node = graph.node(id);
            match check_layer(&node.kind, &graph.input_shapes(id), version) {
                Verdict::Supported => true,
                Verdict::Fallback(reason) => {
                    reasons.push((id, reason));
                    false
                }
            }
        })
        .collect();
    let engines = assign_engines(&flags, min_island);
    let mut segments: Vec<Segment> = Vec::new();
    for (&id, &engine) in layers.iter().zip(engines.iter()) {
        match segments.last_mut() {
            Some(seg) if seg.engine == engine => seg.nodes.push(id),
            _ => segments.push(Segment {
                engine,
                nodes: vec![id],
            }),
        }
    }

    let dla_subgraphs = segments
        .iter()
        .filter(|s| s.engine == EngineKind::Dla)
        .count();
    let transitions = segments.len().saturating_sub(1);

    if dla_subgraphs > max_subgraphs {
        return Err(Error::Dla(format!(
            "engine build failed: {} DLA subgraphs exceed the loadable limit {} \
             (graph `{}`)",
            dla_subgraphs, max_subgraphs, graph.name
        )));
    }

    Ok(EnginePlan {
        segments,
        dla_subgraphs,
        transitions,
        fallback_reasons: reasons,
    })
}

/// [`plan_with_island`] with per-layer verdicts only (`min_island = 1`).
pub fn plan(graph: &Graph, version: DlaVersion, max_subgraphs: usize) -> Result<EnginePlan> {
    plan_with_island(graph, version, max_subgraphs, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::models::pix2pix::{generator, Pix2PixConfig};

    fn paper_plan(variant: GanVariant) -> EnginePlan {
        let g = generator(&Pix2PixConfig::paper(), variant).unwrap();
        plan(&g, DlaVersion::V2, 16).unwrap()
    }

    #[test]
    fn original_pix2pix_falls_back() {
        let p = paper_plan(GanVariant::Original);
        assert!(!p.fully_dla_resident(), "padded deconvs must fall back");
        // All 8 deconvs have padding=1 -> 8 GPU fallback segments expected.
        let gpu_segments = p
            .segments
            .iter()
            .filter(|s| s.engine == EngineKind::Gpu)
            .count();
        assert_eq!(gpu_segments, 8);
        assert!(p.transitions >= 15, "transitions = {}", p.transitions);
        assert!(p
            .fallback_reasons
            .iter()
            .all(|(_, r)| r.contains("padding must be zero")));
    }

    #[test]
    fn modified_variants_fully_resident() {
        for v in [GanVariant::Cropping, GanVariant::Convolution] {
            let p = paper_plan(v);
            assert!(
                p.fully_dla_resident(),
                "{v:?} must be fully DLA-resident (the paper's result)"
            );
            assert_eq!(p.dla_subgraphs, 1);
            assert_eq!(p.transitions, 0);
        }
    }

    #[test]
    fn original_gpu_fraction_nonzero() {
        let p = paper_plan(GanVariant::Original);
        let f = p.gpu_layer_fraction();
        assert!(f > 0.05 && f < 0.5, "gpu fraction {f}");
    }

    #[test]
    fn subgraph_limit_enforced() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        // Original model produces 9 DLA subgraphs; a limit of 4 must fail.
        let err = plan(&g, DlaVersion::V2, 4).unwrap_err();
        assert!(err.to_string().contains("exceed the loadable limit"));
    }

    #[test]
    fn segments_cover_all_compute_layers_in_order() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let p = plan(&g, DlaVersion::V2, 16).unwrap();
        let flattened: Vec<_> = p.segments.iter().flat_map(|s| s.nodes.clone()).collect();
        assert_eq!(flattened, g.compute_layers());
    }

    #[test]
    fn yolov8_plans_with_fallback() {
        let g = crate::models::yolov8::yolov8(&crate::models::yolov8::YoloConfig::nano()).unwrap();
        let p = plan(&g, DlaVersion::V2, 64).unwrap();
        // YOLO has more heterogeneous ops than the GAN; it should still
        // plan (with generous limit) but not be fully resident on v1.
        let p1 = plan(&g, DlaVersion::V1, usize::MAX).unwrap();
        assert!(p1.dla_subgraphs >= p.dla_subgraphs);
    }
}
