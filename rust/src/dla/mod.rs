//! DLA compatibility checking and engine planning.
//!
//! [`rules`] encodes the TensorRT DLA layer restrictions; [`planner`]
//! reproduces the engine-build behaviour those restrictions cause: a model
//! assigned to the DLA is split into alternating DLA / GPU-fallback
//! subgraphs, each transition paying a reformat cost, with execution
//! rejected when the subgraph count exceeds the device limit (16 — the
//! failure mode the paper's §II.C warns about for concurrent models).

pub mod planner;
pub mod rules;

pub use planner::{plan, EnginePlan, Segment};
pub use rules::{check_layer, DlaVersion, Verdict};
