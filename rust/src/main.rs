//! `edgepipe` — CLI entry point (the launcher).
//!
//! ```text
//! edgepipe report <table1|table2|fig9|fig11|table4|table6|all> [--artifacts DIR]
//! edgepipe timeline [--variant V] [--with-yolo]
//! edgepipe run [--config FILE] [--variant V] [--workload W] [--frames N] ...
//! edgepipe check-dla [--variant V]
//! edgepipe schedule [--variant V] [--with-yolo]
//! ```
//!
//! (The vendored offline crate set has no `clap`; argument parsing is the
//! small hand-rolled `Args` below.)

use edgepipe::config::json::{num, obj, s, Json};
use edgepipe::config::{DeviceKind, GanVariant, PipelineConfig, SchedulerKind, Workload};
use edgepipe::dla::{planner, DlaVersion};
use edgepipe::error::Result;
use edgepipe::fleet::{run_fleet, FleetOptions, MigrationPolicy, NodeProfile};
use edgepipe::hw::{self, EngineKind};
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::obs::{ChromeTrace, ObsHub};
use edgepipe::pipeline::{ReconMode, SimBackend, SourceSpec};
use edgepipe::placement::{self, PlacementRequest};
use edgepipe::sched::haxconn;
use edgepipe::serve::{self, ArrivalProcess, ClientSpec, QosClass, ReplanPolicy, ServeOptions};
use edgepipe::session::PipelineBuilder;
use edgepipe::{report, Error};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Minimal `--key value` / `--flag` parser.
struct Args {
    positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args {
            positional,
            options,
            flags,
        }
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

fn usage() -> ! {
    eprintln!(
        "edgepipe — edge GPU aware multi-model MRI pipeline (paper reproduction)

USAGE:
  edgepipe report <table1|table2|fig9|fig11|table4|table6|pipeline|placement|serve|fleet|all>
                  [--artifacts DIR] [--json FILE]
  edgepipe timeline [--variant original|cropping|convolution] [--with-yolo]
  edgepipe run [--config FILE] [--variant V] [--workload W] [--frames N]
               [--streams N] [--artifacts DIR] [--seed N] [--backend pjrt|sim]
               [--source phantom|kspace] [--accel N] [--acs-lines N]
               [--coils N] [--recon zero-filled|grappa] [--json FILE]
               [--trace-out FILE] [--metrics-out FILE]
  edgepipe serve [--config FILE] [--workload W] [--variant V] [--sim]
                 [--duration-frames N] [--clients N]
                 [--profile poisson|burst|ramp] [--rate-fps X]
                 [--qos name:prio[:rate_fps[:deadline_ms]],...]
                 [--no-replan] [--replan-every N] [--min-gain X]
                 [--source phantom|kspace] [--accel N] [--acs-lines N]
                 [--coils N] [--recon zero-filled|grappa]
                 [--time-scale X] [--seed N] [--json FILE]
                 [--trace-out FILE] [--metrics-out FILE]
  edgepipe fleet [--nodes N] [--mix orin,xavier,...] [--clients N]
                 [--duration-frames N] [--profile poisson|burst|ramp]
                 [--rate-fps X] [--check-every N] [--max-backlog N]
                 [--backlog-threshold N] [--no-migrate]
                 [--force-migrate-every N] [--degrade node:at:factor[,...]]
                 [--plan-frames N] [--seed N] [--json FILE]
                 [--trace-out FILE] [--metrics-out FILE]
  edgepipe plan [--device orin|xavier] [--gans N] [--no-yolo]
                [--gan-engines gpu,dla|dla] [--frames N] [--seed N]
                [--source phantom|kspace] [--accel N] [--acs-lines N]
                [--coils N] [--recon zero-filled|grappa]
                [--latency-budget-ms X] [--top K] [--emit-spec FILE]
                [--json FILE]
  edgepipe check-dla [--variant V]
  edgepipe schedule [--variant V] [--with-yolo]

`run` lowers the config through the Session/PipelineBuilder API; pass a
config file with an `instances: [...]` array for arbitrary instance mixes
(`engine`/`engine_index` pin placement — e.g. dla/0 and dla/1), and
`--backend sim` to serve from the latency model with no artifacts.
Workloads: gan-standalone, gan+yolo-naive, two-gans, gan+yolo, dual-gan.
`--source kspace` prepends the accelerated-MRI acquisition front-end on
run/serve/plan: each slice is acquired as R-fold undersampled multi-coil
k-space (--accel, --acs-lines, --coils) and reconstructed in-pipeline
(--recon zero-filled|grappa) before the model chain; the report gains a
`recon` section with per-frame recon time and PSNR/SSIM against the
fully-sampled slice, and `plan` prices the recon stage into admission
pacing and the latency budget.
Engine placement is enforced by the serving arbiter: same-unit instances
serialize, split units contend; per-engine utilization is reported.

`serve` is the long-running front-end: --clients concurrent synthetic
streams (total --duration-frames, shaped by --profile at --rate-fps)
flow through per-class QoS admission into the same coordinator `run`
uses. QoS classes are `name:priority[:rate_fps[:deadline_ms]]`
(priority 0 is never deadline-shed; `-` leaves a slot unset; default:
`interactive:0` unlimited plus `best-effort:1` rate-capped at the
nominal rate with a 250 ms deadline). Admission refusals count as
`shed` — distinct from the pipeline's overload `dropped`. A re-plan
controller watches windowed idle/backlog and swaps to a better searched
placement at a frame boundary (drain-and-switch; disable with
--no-replan). With --sim the arrival schedule is paced by --time-scale
to match the modeled latencies, so long profiles replay in seconds.

`fleet` runs a multi-node cluster entirely on a virtual clock: --nodes
simulated Jetsons (profile per node from --mix, cycled; default
alternating orin/xavier) each plan-on-boot and serve on the event-driven
virtual-clock executor (no threads, no sleeps — thousands of streams per
process), behind a consistent-hash front door. --clients streams (total
--duration-frames shaped by --profile at --rate-fps) hash onto nodes;
every --check-every offered frames the fleet flushes, rolls a window,
and may migrate streams off saturated/degraded nodes (drain-and-switch:
no frame lost, duplicated, or reordered; disable with --no-migrate,
force with --force-migrate-every). --degrade node:at:factor injects a
throttle (e.g. `0:0.5:8` slows node 0 by 8x at t=0.5s). The report
ranks nodes by FPS-per-watt via the cost/power rail model.

`plan` searches placements (variant x engine units x max_batch x route)
instead of hand-writing one: candidates with DLA fallback are rejected
with per-layer reasons, the rest are priced in virtual time, and the
ranked table is printed. `--emit-spec` writes the winning spec as JSON
that reloads through `run --config`; `--gan-engines dla` reserves the GPU
for the detector (the paper's dual-GAN deployment constraint).

Observability: `--trace-out FILE` on run/serve/fleet writes a Chrome
trace-event JSON (load in chrome://tracing or https://ui.perfetto.dev;
process = node, thread = engine unit, async flows = frame lifecycles,
instants = replan/migration/shed/degrade markers). `--metrics-out FILE`
writes checkpoint-aligned JSONL: one `kind=metrics` registry snapshot
per checkpoint interleaved with `kind=event` lines for the structured
event log (replans, migrations, degradations, shed bursts). Either flag also attaches frame-lifecycle
stage stamps, so the report JSON gains a per-stage `stages` breakdown.

CI tracks `rust/BENCH_hotpath.json` as the bench baseline; refresh it by
running `EDGEPIPE_BENCH_SMOKE=1 cargo bench --no-default-features --bench
hotpath` and committing the regenerated file (see the bench-smoke job).
"
    );
    std::process::exit(2)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn variant_of(args: &Args) -> Result<GanVariant> {
    args.opt("variant")
        .map(GanVariant::parse)
        .unwrap_or(Ok(GanVariant::Cropping))
}

/// Apply the acquisition-source flags (`--source phantom|kspace`,
/// `--accel N`, `--acs-lines N`, `--coils N`,
/// `--recon zero-filled|grappa`) onto a spec/config/request source.
/// `--source kspace` starts from the standard R=4 GRAPPA shape; the
/// geometry flags then refine it (and also refine a kspace source loaded
/// from a config file).
fn apply_source_flags(source: &mut SourceSpec, args: &Args) -> Result<()> {
    if let Some(kind) = args.opt("source") {
        *source = match kind {
            "phantom" => SourceSpec::Phantom,
            "kspace" => SourceSpec::kspace(4, ReconMode::Grappa),
            other => {
                return Err(Error::Config(format!(
                    "unknown --source `{other}` (known: phantom, kspace)"
                )));
            }
        };
    }
    if let SourceSpec::Kspace {
        accel,
        acs_lines,
        coils,
        recon,
    } = source
    {
        if let Some(v) = args.opt("accel") {
            *accel = v.parse().map_err(|_| Error::Config("bad --accel".into()))?;
        }
        if let Some(v) = args.opt("acs-lines") {
            *acs_lines = v
                .parse()
                .map_err(|_| Error::Config("bad --acs-lines".into()))?;
        }
        if let Some(v) = args.opt("coils") {
            *coils = v.parse().map_err(|_| Error::Config("bad --coils".into()))?;
        }
        if let Some(v) = args.opt("recon") {
            *recon = ReconMode::parse(v)?;
        }
    } else if ["accel", "acs-lines", "coils", "recon"]
        .iter()
        .any(|k| args.opt(k).is_some())
    {
        return Err(Error::Config(
            "--accel/--acs-lines/--coils/--recon need a kspace source \
             (pass --source kspace or a config with `source: {\"kind\": \"kspace\", ...}`)"
                .into(),
        ));
    }
    Ok(())
}

/// One-line recon front-end summary for `run`/`serve` stdout.
fn print_recon(r: &edgepipe::pipeline::ReconReport) {
    println!(
        "  recon {:<11} R={} acs={} coils={}  {:>6.2} ms/frame  psnr {:>6.2}  ssim {:>6.2}  \
         ({} scored, {} skipped)",
        r.recon, r.accel, r.acs_lines, r.coils, r.recon_ms_per_frame, r.psnr_mean,
        r.ssim_pct_mean, r.scored, r.skipped
    );
}

/// One hub serves both observability flags: either `--trace-out` or
/// `--metrics-out` turns frame-lifecycle stamping on.
fn obs_hub_for(args: &Args) -> Option<Arc<ObsHub>> {
    if args.opt("trace-out").is_some() || args.opt("metrics-out").is_some() {
        Some(Arc::new(ObsHub::new()))
    } else {
        None
    }
}

fn write_trace(path: &str, tr: &ChromeTrace) -> Result<()> {
    std::fs::write(path, tr.to_json().to_compact())?;
    eprintln!("wrote {path} ({} trace event(s))", tr.event_count());
    Ok(())
}

fn write_metrics(path: &str, hub: &ObsHub) -> Result<()> {
    std::fs::write(path, hub.to_jsonl())?;
    eprintln!(
        "wrote {path} ({} snapshot(s), {} event(s))",
        hub.snapshot_count(),
        hub.event_count()
    );
    Ok(())
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "report" => {
            let what = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            let dir = args.opt("artifacts").unwrap_or("artifacts");
            let soc = hw::orin();
            let json = match what {
                "table1" => report::table1(&soc),
                "table2" => report::table2(dir),
                "fig9" | "fig10" => report::fig9_fig10(&soc),
                "fig11" | "fig12" => report::fig11_fig12(&soc),
                "table3" | "table4" | "fig13" => report::table3_table4_fig13(&soc),
                "table5" | "table6" | "fig14" => report::table5_table6_fig14(&soc),
                "pipeline" => report::pipeline_report(&soc),
                "placement" => report::placement_report(&soc),
                "serve" => report::serve_report(&soc),
                "fleet" => report::fleet_report(),
                "all" => report::all_reports(dir),
                other => {
                    return Err(Error::Config(format!("unknown report `{other}`")));
                }
            };
            if let Some(path) = args.opt("json") {
                std::fs::write(path, json.to_pretty())?;
                eprintln!("wrote {path}");
            }
            Ok(())
        }
        "timeline" => {
            let v = variant_of(args)?;
            let soc = hw::orin();
            let a = report::timeline_ascii(&soc, v, args.flag("with-yolo"))?;
            println!("{a}");
            Ok(())
        }
        "run" => {
            let mut cfg = match args.opt("config") {
                Some(path) => PipelineConfig::from_file(std::path::Path::new(path))?,
                None => PipelineConfig::default(),
            };
            if let Some(v) = args.opt("variant") {
                cfg.variant = GanVariant::parse(v)?;
            }
            if let Some(w) = args.opt("workload") {
                cfg.workload = Workload::parse(w)?;
            }
            if let Some(s) = args.opt("scheduler") {
                cfg.scheduler = SchedulerKind::parse(s)?;
            }
            if let Some(n) = args.opt("frames") {
                cfg.frames = n
                    .parse()
                    .map_err(|_| Error::Config("bad --frames".into()))?;
            }
            if let Some(n) = args.opt("streams") {
                cfg.streams = n
                    .parse()
                    .map_err(|_| Error::Config("bad --streams".into()))?;
            }
            if let Some(d) = args.opt("artifacts") {
                cfg.artifact_dir = d.to_string();
            }
            if let Some(seed) = args.opt("seed") {
                cfg.seed = seed.parse().map_err(|_| Error::Config("bad --seed".into()))?;
            }
            apply_source_flags(&mut cfg.source, args)?;
            cfg.validate()?;
            eprintln!("config: {}", cfg.to_json().to_compact());
            let mut builder = PipelineBuilder::from_config(&cfg);
            match args.opt("backend").unwrap_or("pjrt") {
                "pjrt" => {}
                "sim" => {
                    let soc = match cfg.device {
                        DeviceKind::Orin => hw::orin(),
                        DeviceKind::Xavier => hw::xavier(),
                    };
                    builder = builder.backend(Arc::new(SimBackend::new(soc)));
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown backend `{other}` (known: pjrt, sim)"
                    )));
                }
            }
            let session = builder.build()?;
            let hub = obs_hub_for(args);
            let rep = match &hub {
                Some(h) => session.run_observed(Some(Arc::clone(&h.stages)))?,
                None => session.run()?,
            };
            println!(
                "processed {} frames in {:.2}s ({} dropped, {} shed) [{} backend]",
                rep.total_frames,
                rep.wall_seconds,
                rep.dropped,
                rep.shed,
                session.backend_name()
            );
            for inst in &rep.instances {
                println!(
                    "  {:<12} {:>6} frames  {:>4} dropped  {:>8.2} fps  lat p50 {:>7.2} ms  \
                     p99 {:>7.2} ms  psnr {:>6.2}  ssim {:>6.2}",
                    inst.label,
                    inst.frames,
                    inst.dropped,
                    inst.fps,
                    inst.latency_ms_p50,
                    inst.latency_ms_p99,
                    inst.psnr_mean,
                    inst.ssim_pct_mean
                );
            }
            for e in &rep.engines {
                println!(
                    "  engine {:<6} util {:>5.1}%  busy {:>8.2} ms  {:>5} dispatches  \
                     mean block {:>6.2} ms  idle gap mean {:>6.2} ms  p99 {:>6.2} ms",
                    e.label,
                    e.utilization * 100.0,
                    e.busy_seconds * 1e3,
                    e.dispatches,
                    e.mean_block_ms,
                    e.idle_gap_ms_mean,
                    e.idle_gap_ms_p99
                );
            }
            if let Some(r) = &rep.recon {
                print_recon(r);
            }
            if let Some(st) = &rep.stages {
                println!("  stages: {}", st.summary());
            }
            if let Some(h) = &hub {
                h.snapshot_at(rep.wall_seconds);
                if let Some(path) = args.opt("trace-out") {
                    let mut tr = ChromeTrace::new();
                    tr.process(0, &format!("edgepipe run [{}]", session.backend_name()));
                    let labels: Vec<String> = session
                        .spec()
                        .instances
                        .iter()
                        .map(|i| i.label.clone())
                        .collect();
                    tr.add_timeline(0, &rep.timeline, &labels);
                    // One async flow per frame: first dispatch start to
                    // last dispatch end across all instances.
                    let mut frames: BTreeMap<usize, (f64, f64)> = BTreeMap::new();
                    for sp in rep.timeline.spans.iter().filter(|sp| !sp.is_transition) {
                        let e = frames.entry(sp.frame).or_insert((sp.t0, sp.t1));
                        e.0 = e.0.min(sp.t0);
                        e.1 = e.1.max(sp.t1);
                    }
                    for (frame, (t0, t1)) in &frames {
                        tr.flow(
                            0,
                            *frame as u64,
                            "frame",
                            *t0,
                            *t1,
                            obj(vec![("frame", num(*frame as f64))]),
                        );
                    }
                    write_trace(path, &tr)?;
                }
                if let Some(path) = args.opt("metrics-out") {
                    write_metrics(path, h)?;
                }
            }
            if let Some(path) = args.opt("json") {
                std::fs::write(path, rep.to_json().to_pretty())?;
                eprintln!("wrote {path}");
            }
            Ok(())
        }
        "serve" => {
            let mut cfg = match args.opt("config") {
                Some(path) => PipelineConfig::from_file(std::path::Path::new(path))?,
                None => PipelineConfig::default(),
            };
            if let Some(v) = args.opt("variant") {
                cfg.variant = GanVariant::parse(v)?;
            }
            if let Some(w) = args.opt("workload") {
                cfg.workload = Workload::parse(w)?;
            }
            if let Some(seed) = args.opt("seed") {
                cfg.seed = seed.parse().map_err(|_| Error::Config("bad --seed".into()))?;
            }
            apply_source_flags(&mut cfg.source, args)?;
            cfg.validate()?;
            let (soc, version) = match cfg.device {
                DeviceKind::Orin => (hw::orin(), DlaVersion::V2),
                DeviceKind::Xavier => (hw::xavier(), DlaVersion::V1),
            };
            let use_sim = args.flag("sim") || args.opt("backend") == Some("sim");
            // Fast-forward pacing is a --sim affordance: against a real
            // backend the schedule must replay in real time (1.0), or a
            // nominal load would arrive 20x compressed.
            let time_scale: f64 = args
                .opt("time-scale")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --time-scale".into())))
                .unwrap_or(Ok(if use_sim { 0.05 } else { 1.0 }))?;
            let mut builder = PipelineBuilder::from_config(&cfg);
            if use_sim {
                builder = builder
                    .backend(Arc::new(SimBackend::new(soc.clone()).with_time_scale(time_scale)));
            }
            let session = builder.build()?;

            // Load shape: --duration-frames split across --clients, each
            // shaped by --profile around the nominal per-client rate.
            let duration: usize = args
                .opt("duration-frames")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --duration-frames".into())))
                .unwrap_or(Ok(1024))?;
            let n_clients: usize = args
                .opt("clients")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --clients".into())))
                .unwrap_or(Ok(3))?;
            let n_clients = n_clients.max(1);
            let rate_fps: f64 = args
                .opt("rate-fps")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --rate-fps".into())))
                .unwrap_or(Ok(120.0))?;
            let profile = args.opt("profile").unwrap_or("poisson");
            let per_rate = rate_fps / n_clients as f64;
            let base = duration / n_clients;
            let extra = duration % n_clients;
            let mut opts = ServeOptions::new(soc.clone(), version);
            opts.time_scale = time_scale;
            opts.seed = cfg.seed;
            opts.qos = match args.opt("qos") {
                Some(list) => list
                    .split(',')
                    .map(QosClass::parse)
                    .collect::<Result<Vec<_>>>()?,
                None => vec![
                    QosClass::unlimited("interactive", 0),
                    QosClass::unlimited("best-effort", 1)
                        .rate_limited(per_rate, (per_rate * 0.25).max(4.0))
                        .with_deadline_ms(250.0),
                ],
            };
            for i in 0..n_clients {
                let frames = base + usize::from(i < extra);
                let arrivals = match profile {
                    "poisson" => ArrivalProcess::Poisson { rate_fps: per_rate },
                    "burst" => ArrivalProcess::Burst {
                        burst_fps: per_rate * 4.0,
                        burst_len: 32,
                        idle_seconds: 0.75 * 32.0 / per_rate,
                    },
                    "ramp" => ArrivalProcess::Ramp {
                        start_fps: per_rate * 0.25,
                        end_fps: per_rate * 2.5,
                    },
                    other => {
                        return Err(Error::Config(format!(
                            "unknown profile `{other}` (known: poisson, burst, ramp)"
                        )));
                    }
                };
                opts.clients.push(
                    ClientSpec::new(format!("client-{i}"), frames, arrivals)
                        .qos_class(i % opts.qos.len()),
                );
            }
            opts.replan = if args.flag("no-replan") {
                ReplanPolicy::disabled()
            } else {
                let mut p = ReplanPolicy::default();
                if let Some(n) = args.opt("replan-every") {
                    p.check_every_frames = n
                        .parse()
                        .map_err(|_| Error::Config("bad --replan-every".into()))?;
                }
                if let Some(g) = args.opt("min-gain") {
                    p.min_gain =
                        g.parse().map_err(|_| Error::Config("bad --min-gain".into()))?;
                }
                p
            };
            let hub = obs_hub_for(args);
            if let Some(h) = &hub {
                opts.obs = Some(Arc::clone(h));
            }

            let rep = serve::serve(session, opts)?;
            println!(
                "served {} offered / {} completed / {} shed ({} rate, {} deadline) in {:.2}s",
                rep.offered,
                rep.completed,
                rep.shed,
                rep.shed_rate_limit,
                rep.shed_deadline,
                rep.wall_seconds
            );
            println!(
                "latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  ({} window(s), {} re-plan(s))",
                rep.latency_ms_p50,
                rep.latency_ms_p95,
                rep.latency_ms_p99,
                rep.windows.len(),
                rep.replans.len()
            );
            if let Some(r) = &rep.recon {
                print_recon(r);
            }
            for ev in &rep.replans {
                println!(
                    "  re-plan @frame {} ({:.2}s): {} -> {}  [{}] predicted {:.1} -> {:.1} fps",
                    ev.at_frame,
                    ev.at_seconds,
                    ev.from_key,
                    ev.to_key,
                    ev.reason,
                    ev.predicted_fps_before,
                    ev.predicted_fps_after
                );
            }
            if let Some(last) = rep.windows.last() {
                for (unit, busy) in &last.engine_busy {
                    println!("  {:<5} final-window busy {:>5.1}%", unit, busy * 100.0);
                }
            }
            if let Some(st) = &rep.stages {
                println!("stage breakdown: {}", st.summary());
            }
            if let Some(h) = &hub {
                if let Some(path) = args.opt("trace-out") {
                    let mut tr = ChromeTrace::new();
                    tr.process(0, "edgepipe serve");
                    // Instance labels change across drain-and-switch
                    // phases, so unit slices keep generic `inst{n}` names.
                    tr.add_timeline(0, &rep.timeline, &[]);
                    for ev in &rep.replans {
                        tr.instant(0, "control", "replan", "replan", ev.at_seconds, ev.to_json());
                    }
                    // One async flow per completed frame from the retained
                    // completion tail (bounded by --telemetry capacity).
                    const MAX_FLOWS: usize = 20_000;
                    for c in rep.completions.iter().take(MAX_FLOWS) {
                        let id = ((c.instance as u64) << 56)
                            | ((c.stream as u64) << 40)
                            | (c.frame_id & ((1 << 40) - 1));
                        tr.flow(
                            0,
                            id,
                            "frame",
                            (c.t - c.latency_s).max(0.0),
                            c.t,
                            obj(vec![
                                ("stream", num(c.stream as f64)),
                                ("frame", num(c.frame_id as f64)),
                                ("instance", num(c.instance as f64)),
                            ]),
                        );
                    }
                    if rep.completions.len() > MAX_FLOWS {
                        eprintln!(
                            "trace: kept {MAX_FLOWS} of {} frame flows",
                            rep.completions.len()
                        );
                    }
                    write_trace(path, &tr)?;
                }
                if let Some(path) = args.opt("metrics-out") {
                    write_metrics(path, h)?;
                }
            }
            if let Some(path) = args.opt("json") {
                std::fs::write(path, rep.to_json().to_pretty())?;
                eprintln!("wrote {path}");
            }
            Ok(())
        }
        "fleet" => {
            // Fleet shape: --nodes sized, profiles cycled from --mix.
            let n_nodes: usize = args
                .opt("nodes")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --nodes".into())))
                .unwrap_or(Ok(4))?;
            let n_nodes = n_nodes.max(1);
            let mix: Vec<NodeProfile> = match args.opt("mix") {
                Some(list) => list
                    .split(',')
                    .map(|p| {
                        NodeProfile::parse(p.trim()).ok_or_else(|| {
                            Error::Config(format!(
                                "unknown profile `{p}` in --mix (known: orin, xavier)"
                            ))
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                None => vec![NodeProfile::Orin, NodeProfile::Xavier],
            };
            if mix.is_empty() {
                return Err(Error::Config("--mix needs at least one profile".into()));
            }
            let profiles: Vec<NodeProfile> =
                (0..n_nodes).map(|i| mix[i % mix.len()]).collect();
            let mut opts = FleetOptions::new(profiles);

            if let Some(seed) = args.opt("seed") {
                opts.seed = seed.parse().map_err(|_| Error::Config("bad --seed".into()))?;
            }
            if let Some(n) = args.opt("check-every") {
                opts.check_every = n
                    .parse()
                    .map_err(|_| Error::Config("bad --check-every".into()))?;
            }
            if let Some(n) = args.opt("max-backlog") {
                opts.max_backlog = n
                    .parse()
                    .map_err(|_| Error::Config("bad --max-backlog".into()))?;
            }
            if let Some(n) = args.opt("plan-frames") {
                opts.plan_frames = n
                    .parse()
                    .map_err(|_| Error::Config("bad --plan-frames".into()))?;
            }
            opts.migration = if args.flag("no-migrate") {
                MigrationPolicy::disabled()
            } else {
                let mut p = MigrationPolicy::default();
                if let Some(n) = args.opt("backlog-threshold") {
                    p.backlog_threshold = n
                        .parse()
                        .map_err(|_| Error::Config("bad --backlog-threshold".into()))?;
                }
                if let Some(n) = args.opt("force-migrate-every") {
                    p.force_every_checks = Some(
                        n.parse()
                            .map_err(|_| Error::Config("bad --force-migrate-every".into()))?,
                    );
                }
                p
            };
            // --degrade node:at_seconds:factor[,...]
            if let Some(list) = args.opt("degrade") {
                for part in list.split(',') {
                    let fields: Vec<&str> = part.split(':').collect();
                    if fields.len() != 3 {
                        return Err(Error::Config(format!(
                            "bad --degrade entry `{part}` (want node:at:factor)"
                        )));
                    }
                    opts.degradations.push(edgepipe::fleet::DegradationEvent {
                        node: fields[0]
                            .parse()
                            .map_err(|_| Error::Config("bad --degrade node".into()))?,
                        at_seconds: fields[1]
                            .parse()
                            .map_err(|_| Error::Config("bad --degrade at".into()))?,
                        slowdown: fields[2]
                            .parse()
                            .map_err(|_| Error::Config("bad --degrade factor".into()))?,
                    });
                }
            }

            // Client load, shaped like `serve`'s.
            let duration: usize = args
                .opt("duration-frames")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --duration-frames".into())))
                .unwrap_or(Ok(4096))?;
            let n_clients: usize = args
                .opt("clients")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --clients".into())))
                .unwrap_or(Ok(32))?;
            let n_clients = n_clients.max(1);
            let rate_fps: f64 = args
                .opt("rate-fps")
                .map(|v| v.parse().map_err(|_| Error::Config("bad --rate-fps".into())))
                .unwrap_or(Ok(600.0))?;
            let profile = args.opt("profile").unwrap_or("poisson");
            let per_rate = rate_fps / n_clients as f64;
            let base = duration / n_clients;
            let extra = duration % n_clients;
            for i in 0..n_clients {
                let frames = base + usize::from(i < extra);
                if frames == 0 {
                    continue;
                }
                let arrivals = match profile {
                    "poisson" => ArrivalProcess::Poisson { rate_fps: per_rate },
                    "burst" => ArrivalProcess::Burst {
                        burst_fps: per_rate * 4.0,
                        burst_len: 32,
                        idle_seconds: 0.75 * 32.0 / per_rate,
                    },
                    "ramp" => ArrivalProcess::Ramp {
                        start_fps: per_rate * 0.25,
                        end_fps: per_rate * 2.5,
                    },
                    other => {
                        return Err(Error::Config(format!(
                            "unknown profile `{other}` (known: poisson, burst, ramp)"
                        )));
                    }
                };
                opts.clients
                    .push(ClientSpec::new(format!("client-{i}"), frames, arrivals));
            }
            opts.class_names = vec!["default".into()];
            let hub = obs_hub_for(args);
            opts.obs = hub.clone();
            opts.record_spans = args.opt("trace-out").is_some();

            let rep = run_fleet(&opts)?;
            println!(
                "fleet: {} node(s), {} stream(s) — {} offered / {} completed / {} shed, \
                 {} migration(s), {:.1} virtual fps in {:.2} virtual s ({:.2}s wall)",
                rep.nodes.len(),
                rep.streams,
                rep.offered,
                rep.completed,
                rep.shed,
                rep.migrations.len(),
                rep.fps,
                rep.virtual_seconds,
                rep.wall_seconds
            );
            println!(
                "latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms  ({} window(s))",
                rep.latency_ms_p50,
                rep.latency_ms_p95,
                rep.latency_ms_p99,
                rep.windows.len()
            );
            println!(
                "{:<5} {:<7} {:>9} {:>9} {:>7} {:>8} {:>9} {:>11} {:>6} {:>6}  health",
                "node", "profile", "offered", "completed", "shed", "fps", "power W", "fps/W", "in", "out"
            );
            for &i in &rep.ranking() {
                let n = &rep.nodes[i];
                println!(
                    "{:<5} {:<7} {:>9} {:>9} {:>7} {:>8.1} {:>9.2} {:>11.2} {:>6} {:>6}  {}",
                    n.node,
                    n.profile,
                    n.offered,
                    n.completed,
                    n.shed,
                    n.fps,
                    n.power_w,
                    n.fps_per_watt,
                    n.migrations_in,
                    n.migrations_out,
                    n.health
                );
            }
            for ev in &rep.migrations {
                println!(
                    "  migrate @{:.3}s: stream {} node {} -> {} [{}]",
                    ev.at_seconds, ev.stream, ev.from_node, ev.to_node, ev.reason
                );
            }
            if let Some(st) = &rep.stages {
                println!("stage breakdown: {}", st.summary());
            }
            if let Some(h) = &hub {
                if let Some(path) = args.opt("trace-out") {
                    let mut tr = ChromeTrace::new();
                    for (node_id, tl) in &rep.timelines {
                        let profile = rep
                            .nodes
                            .iter()
                            .find(|n| n.node == *node_id)
                            .map(|n| n.profile.as_str())
                            .unwrap_or("node");
                        tr.process(*node_id as u64, &format!("node{node_id} [{profile}]"));
                        tr.add_timeline(*node_id as u64, tl, &[]);
                    }
                    for ev in &rep.migrations {
                        tr.instant(
                            ev.from_node as u64,
                            "control",
                            "migration",
                            "migration",
                            ev.at_seconds,
                            ev.to_json(),
                        );
                    }
                    write_trace(path, &tr)?;
                }
                if let Some(path) = args.opt("metrics-out") {
                    write_metrics(path, h)?;
                }
            }
            if let Some(path) = args.opt("json") {
                std::fs::write(path, rep.to_json().to_pretty())?;
                eprintln!("wrote {path}");
            }
            Ok(())
        }
        "plan" => {
            let device = args
                .opt("device")
                .map(DeviceKind::parse)
                .unwrap_or(Ok(DeviceKind::Orin))?;
            let (soc, version) = match device {
                DeviceKind::Orin => (hw::orin(), DlaVersion::V2),
                DeviceKind::Xavier => (hw::xavier(), DlaVersion::V1),
            };
            let mut req = PlacementRequest::new(soc, version);
            if let Some(n) = args.opt("gans") {
                req.gans = n.parse().map_err(|_| Error::Config("bad --gans".into()))?;
            }
            if args.flag("no-yolo") {
                req.with_yolo = false;
            }
            if let Some(list) = args.opt("gan-engines") {
                let mut engines = Vec::new();
                for part in list.split(',') {
                    let e = EngineKind::parse(part.trim()).ok_or_else(|| {
                        Error::Config(format!("unknown engine `{part}` in --gan-engines"))
                    })?;
                    engines.push(e);
                }
                req.gan_engines = engines;
            }
            if let Some(n) = args.opt("frames") {
                req.frames = n.parse().map_err(|_| Error::Config("bad --frames".into()))?;
            }
            if let Some(x) = args.opt("latency-budget-ms") {
                req.latency_budget_ms = Some(
                    x.parse()
                        .map_err(|_| Error::Config("bad --latency-budget-ms".into()))?,
                );
            }
            if let Some(seed) = args.opt("seed") {
                req.seed = seed.parse().map_err(|_| Error::Config("bad --seed".into()))?;
            }
            apply_source_flags(&mut req.source, args)?;
            let top: usize = args
                .opt("top")
                .map(|s| s.parse().map_err(|_| Error::Config("bad --top".into())))
                .unwrap_or(Ok(10))?;

            let outcome = placement::plan(&req)?;
            println!(
                "plan: {} gan(s){} on {} ({} candidate(s) scored, {} rejected, {} pruned)",
                req.gans,
                if req.with_yolo { " + yolo" } else { "" },
                req.soc.name,
                outcome.ranked.len(),
                outcome.rejected.len(),
                outcome.pruned
            );
            if outcome.eval.recon_ms_per_frame > 0.0 {
                println!(
                    "recon front-end [{}]: {:.2} ms/frame priced into admission pacing \
                     and the latency budget",
                    req.source.kind(),
                    outcome.eval.recon_ms_per_frame
                );
            }
            println!(
                "{:<4} {:<44} {:>9} {:>10} {:>6}  units (predicted util%)",
                "rank", "candidate", "fps", "idle ms", "trans"
            );
            for (i, sc) in outcome.ranked.iter().take(top).enumerate() {
                println!(
                    "{:<4} {:<44} {:>9.1} {:>10.2} {:>6}  {}",
                    i + 1,
                    sc.candidate_key,
                    sc.eval.predicted_fps,
                    sc.eval.idle_gap_total_ms,
                    sc.eval.transitions,
                    sc.eval.unit_summary()
                );
            }
            for (key, reason) in &outcome.rejected {
                println!("  rejected {key}: {reason}");
            }

            // Planned vs hand-written preset: the dual_gan comparison the
            // report's `placement` section tracks.
            let preset_fps = if req.gans == 2 && req.with_yolo {
                let preset = Workload::DualGan.spec(GanVariant::Cropping);
                let eval = placement::evaluate(&preset, &req.soc, req.frames)?;
                println!(
                    "planned best {:.1} predicted fps vs dual_gan preset {:.1} ({:+.1}%)",
                    outcome.eval.predicted_fps,
                    eval.predicted_fps,
                    (outcome.eval.predicted_fps / eval.predicted_fps - 1.0) * 100.0
                );
                Some(eval.predicted_fps)
            } else {
                None
            };

            if let Some(path) = args.opt("emit-spec") {
                // Carry the device the plan was priced on: without it,
                // `run --config` would serve a Xavier-planned spec on the
                // config-default Orin latency tables.
                let mut doc = outcome.spec.to_json();
                if let Json::Obj(map) = &mut doc {
                    map.insert("device".into(), s(device.name()));
                }
                std::fs::write(path, doc.to_pretty())?;
                eprintln!("wrote {path} (reloads via `run --config {path}`)");
            }
            if let Some(path) = args.opt("json") {
                let mut pairs = vec![
                    ("device", s(device.name())),
                    ("outcome", outcome.to_json()),
                ];
                if let Some(fps) = preset_fps {
                    pairs.push(("preset_dual_gan_fps", num(fps)));
                }
                std::fs::write(path, obj(pairs).to_pretty())?;
                eprintln!("wrote {path}");
            }
            Ok(())
        }
        "check-dla" => {
            let v = variant_of(args)?;
            let g = generator(&Pix2PixConfig::paper(), v)?;
            let plan = planner::plan(&g, DlaVersion::V2, 16)?;
            println!(
                "model `{}`: {} compute layers, {} DLA subgraphs, {} transitions, fully resident: {}",
                g.name,
                g.compute_layers().len(),
                plan.dla_subgraphs,
                plan.transitions,
                plan.fully_dla_resident()
            );
            for (id, reason) in &plan.fallback_reasons {
                println!("  fallback {:>4} {:<24} {}", id, g.node(*id).name, reason);
            }
            Ok(())
        }
        "schedule" => {
            let v = variant_of(args)?;
            let soc = hw::orin();
            let g = generator(&Pix2PixConfig::paper(), v)?;
            let (sched, ss) = if args.flag("with-yolo") {
                let y = yolov8(&YoloConfig::nano())?;
                haxconn::gan_plus_yolo(&g, &y, &soc, DlaVersion::V2)?
            } else {
                haxconn::two_gans(&g, &soc, DlaVersion::V2)?
            };
            println!(
                "steady state: period {:.3} ms, busy gpu {:.3} ms, busy dla {:.3} ms, transitions {}",
                ss.period * 1e3,
                ss.busy_gpu * 1e3,
                ss.busy_dla * 1e3,
                ss.transitions
            );
            for inst in &sched.instances {
                let (d2g, g2d) = inst.partition_points();
                println!(
                    "  {:<12} segments {:?}  DLA->GPU {:?}  GPU->DLA {:?}",
                    inst.label,
                    inst.segments
                        .iter()
                        .map(|sp| format!("{}[{},{})", sp.engine, sp.start, sp.end))
                        .collect::<Vec<_>>(),
                    d2g,
                    g2d
                );
            }
            Ok(())
        }
        _ => usage(),
    }
}
