//! Per-class QoS admission control for the serve loop.
//!
//! Every offered frame passes [`AdmissionController::decide`] *before*
//! routing. A frame is shed for one of two reasons, both counted per
//! class and both surfaced as `shed` in the metrics/report — never as
//! `dropped_overload` (that counter belongs to full worker queues inside
//! the pipeline; see [`crate::pipeline::metrics`]):
//!
//! * **rate limit** — the class's token bucket is empty. Buckets refill
//!   in *model time* (the arrival schedule's clock), so the same load
//!   profile sheds the same frames regardless of the serve time scale;
//! * **deadline** — the class has a latency deadline and the current
//!   backlog-estimated wait exceeds it (deadline-aware shedding: work
//!   that would miss its deadline anyway is refused while it is still
//!   cheap, reusing the droppable-fanout philosophy of the driver's
//!   non-primary copies). Deadlines are **model-time** milliseconds:
//!   the serve loop converts its wall-clock wait estimate by the time
//!   scale, so a fast-forwarded sim run sheds the same frames a
//!   real-time run would.
//!
//! Priority is the class's rank (0 = highest, e.g. the lossless
//! reconstruction stream). Priority-0 classes are exempt from deadline
//! shedding — under pressure the best-effort classes thin out first,
//! which is exactly the paper's "reconstruction never drops" contract.

// Admission decisions run once per offered frame.
#![deny(clippy::unwrap_used)]

use crate::config::json::{arr, num, obj, s, Json};
use crate::error::{Error, Result};

/// Why admission refused a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Token bucket empty: the client exceeded its class's rate.
    RateLimit,
    /// Estimated queueing delay exceeds the class deadline.
    Deadline,
}

/// One QoS class.
#[derive(Debug, Clone)]
pub struct QosClass {
    pub name: String,
    /// Rank, 0 = highest. Priority-0 classes are never deadline-shed.
    pub priority: usize,
    /// Sustained admission rate in frames/s of model time (`None` =
    /// unlimited).
    pub rate_fps: Option<f64>,
    /// Token-bucket capacity in frames (how much burst the class may
    /// carry above its sustained rate).
    pub burst: f64,
    /// Latency deadline in milliseconds of **model time** (`None` =
    /// none) — scale-invariant under the serve loop's time scale.
    pub deadline_ms: Option<f64>,
}

impl QosClass {
    /// An unlimited class (no rate cap, no deadline).
    pub fn unlimited(name: impl Into<String>, priority: usize) -> Self {
        QosClass {
            name: name.into(),
            priority,
            rate_fps: None,
            burst: 1.0,
            deadline_ms: None,
        }
    }

    /// Cap the sustained admission rate (token bucket of `burst` frames).
    pub fn rate_limited(mut self, rate_fps: f64, burst: f64) -> Self {
        self.rate_fps = Some(rate_fps);
        self.burst = burst.max(1.0);
        self
    }

    /// Shed when the estimated wait exceeds `deadline_ms` (ignored for
    /// priority 0).
    pub fn with_deadline_ms(mut self, deadline_ms: f64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Parse the CLI form `name:priority[:rate_fps[:deadline_ms]]` —
    /// `-` for "unset" in either numeric slot.
    pub fn parse(spec: &str) -> Result<QosClass> {
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.len() < 2 || parts.len() > 4 || parts[0].is_empty() {
            return Err(Error::Config(format!(
                "bad QoS class `{spec}` (want name:priority[:rate_fps[:deadline_ms]])"
            )));
        }
        let priority: usize = parts[1]
            .parse()
            .map_err(|_| Error::Config(format!("bad QoS priority in `{spec}`")))?;
        let mut class = QosClass::unlimited(parts[0], priority);
        if let Some(r) = parts.get(2).filter(|r| **r != "-") {
            let rate: f64 = r
                .parse()
                .map_err(|_| Error::Config(format!("bad QoS rate_fps in `{spec}`")))?;
            class = class.rate_limited(rate, (rate * 0.25).max(4.0));
        }
        if let Some(d) = parts.get(3).filter(|d| **d != "-") {
            let deadline: f64 = d
                .parse()
                .map_err(|_| Error::Config(format!("bad QoS deadline_ms in `{spec}`")))?;
            class = class.with_deadline_ms(deadline);
        }
        Ok(class)
    }
}

/// Per-class running counters.
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    pub admitted: usize,
    pub shed_rate_limit: usize,
    pub shed_deadline: usize,
}

#[derive(Debug)]
struct Bucket {
    tokens: f64,
    last_t: f64,
}

/// Stateful admission controller over a class table.
#[derive(Debug)]
pub struct AdmissionController {
    classes: Vec<QosClass>,
    buckets: Vec<Bucket>,
    stats: Vec<ClassStats>,
}

impl AdmissionController {
    pub fn new(classes: Vec<QosClass>) -> Result<AdmissionController> {
        if classes.is_empty() {
            return Err(Error::Config("admission needs at least one QoS class".into()));
        }
        let buckets = classes
            .iter()
            .map(|c| Bucket {
                tokens: c.burst,
                last_t: 0.0,
            })
            .collect();
        let stats = classes.iter().map(|_| ClassStats::default()).collect();
        Ok(AdmissionController {
            classes,
            buckets,
            stats,
        })
    }

    pub fn classes(&self) -> &[QosClass] {
        &self.classes
    }

    /// Admit or shed one frame of `class` arriving at model time `now`,
    /// with the caller's current backlog-estimated wait. `None` = admit.
    pub fn decide(&mut self, class: usize, now: f64, est_wait_ms: f64) -> Option<ShedReason> {
        let c = &self.classes[class];
        // Deadline first: a frame that would miss its deadline should not
        // spend a token either.
        if c.priority > 0 {
            if let Some(deadline) = c.deadline_ms {
                if est_wait_ms > deadline {
                    self.stats[class].shed_deadline += 1;
                    return Some(ShedReason::Deadline);
                }
            }
        }
        if let Some(rate) = c.rate_fps {
            let b = &mut self.buckets[class];
            b.tokens = (b.tokens + (now - b.last_t).max(0.0) * rate).min(c.burst);
            b.last_t = now;
            if b.tokens < 1.0 {
                self.stats[class].shed_rate_limit += 1;
                return Some(ShedReason::RateLimit);
            }
            b.tokens -= 1.0;
        }
        self.stats[class].admitted += 1;
        None
    }

    pub fn stats(&self) -> &[ClassStats] {
        &self.stats
    }

    pub fn shed_total(&self) -> usize {
        self.stats
            .iter()
            .map(|s| s.shed_rate_limit + s.shed_deadline)
            .sum()
    }

    /// Per-class JSON rows for the serve report.
    pub fn to_json(&self) -> Json {
        arr(self
            .classes
            .iter()
            .zip(self.stats.iter())
            .map(|(c, st)| class_row(c, st))
            .collect())
    }
}

/// One class's JSON row — the single writer shared by
/// [`AdmissionController::to_json`] and the serve report, so the two
/// cannot drift.
pub fn class_row(class: &QosClass, stats: &ClassStats) -> Json {
    obj(vec![
        ("name", s(&class.name)),
        ("priority", num(class.priority as f64)),
        ("admitted", num(stats.admitted as f64)),
        ("shed_rate_limit", num(stats.shed_rate_limit as f64)),
        ("shed_deadline", num(stats.shed_deadline as f64)),
    ])
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_sheds_above_rate_and_recovers() {
        // 10 fps, burst of 2: a 20-frame blast at t=0 admits 2, sheds 18;
        // one second later two more tokens have accrued.
        let mut ac = AdmissionController::new(vec![
            QosClass::unlimited("rt", 1).rate_limited(10.0, 2.0)
        ])
        .unwrap();
        let mut admitted = 0;
        for _ in 0..20 {
            if ac.decide(0, 0.0, 0.0).is_none() {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 2);
        assert_eq!(ac.stats()[0].shed_rate_limit, 18);
        assert!(ac.decide(0, 1.0, 0.0).is_none(), "bucket must refill over time");
        // sustained pacing at the configured rate admits everything
        let mut ac = AdmissionController::new(vec![
            QosClass::unlimited("rt", 1).rate_limited(10.0, 2.0)
        ])
        .unwrap();
        for i in 0..50 {
            assert!(ac.decide(0, 10.0 + i as f64 * 0.1, 0.0).is_none(), "frame {i}");
        }
    }

    #[test]
    fn deadline_sheds_best_effort_but_never_priority_zero() {
        let mut ac = AdmissionController::new(vec![
            QosClass::unlimited("recon", 0).with_deadline_ms(100.0),
            QosClass::unlimited("bulk", 2).with_deadline_ms(100.0),
        ])
        .unwrap();
        // backlog estimate way past the deadline
        assert!(ac.decide(0, 0.0, 500.0).is_none(), "priority 0 is lossless");
        assert_eq!(ac.decide(1, 0.0, 500.0), Some(ShedReason::Deadline));
        assert!(ac.decide(1, 0.0, 50.0).is_none(), "under deadline admits");
        assert_eq!(ac.stats()[1].shed_deadline, 1);
        assert_eq!(ac.shed_total(), 1);
    }

    #[test]
    fn parse_cli_forms() {
        let c = QosClass::parse("recon:0").unwrap();
        assert_eq!(c.name, "recon");
        assert_eq!(c.priority, 0);
        assert!(c.rate_fps.is_none() && c.deadline_ms.is_none());
        let c = QosClass::parse("bulk:2:120:250").unwrap();
        assert_eq!(c.rate_fps, Some(120.0));
        assert_eq!(c.deadline_ms, Some(250.0));
        let c = QosClass::parse("mid:1:-:300").unwrap();
        assert!(c.rate_fps.is_none());
        assert_eq!(c.deadline_ms, Some(300.0));
        assert!(QosClass::parse("oops").is_err());
        assert!(QosClass::parse(":1").is_err());
        assert!(QosClass::parse("x:notanumber").is_err());
    }

    #[test]
    fn stats_json_is_parseable() {
        let mut ac = AdmissionController::new(vec![
            QosClass::unlimited("a", 0),
            QosClass::unlimited("b", 1).rate_limited(1.0, 1.0),
        ])
        .unwrap();
        ac.decide(0, 0.0, 0.0);
        ac.decide(1, 0.0, 0.0);
        ac.decide(1, 0.0, 0.0);
        let txt = ac.to_json().to_compact();
        crate::config::json::Json::parse(&txt).unwrap();
        assert_eq!(ac.stats()[0].admitted, 1);
        assert_eq!(ac.stats()[1].shed_rate_limit, 1);
    }
}
