//! Synthetic client streams for the serve loop.
//!
//! Each [`ClientSpec`] describes one hospital-style client: a frame
//! budget, a QoS class, and an [`ArrivalProcess`] shaping *when* its
//! frames show up. [`schedule`] expands every client deterministically
//! (seeded) and merges the arrivals into one time-ordered sequence, which
//! the serve loop replays — paced by its time scale — against admission
//! control and the streaming core. Times are in **model seconds** (the
//! load profile's own clock); the serve loop multiplies by its
//! `time_scale` when pacing real threads, so the same profile runs at
//! full speed on hardware and in fast-forward under the sim backend.

// Client schedules feed the serve loop's admission path.
#![deny(clippy::unwrap_used)]

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// When a client's frames arrive.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at `rate_fps` (exponential inter-arrival
    /// gaps) — steady hospital load.
    Poisson { rate_fps: f64 },
    /// `burst_len` back-to-back frames at `burst_fps`, then
    /// `idle_seconds` of silence — scanner batches landing at once.
    Burst {
        burst_fps: f64,
        burst_len: usize,
        idle_seconds: f64,
    },
    /// Rate ramps linearly from `start_fps` to `end_fps` across the
    /// client's frame budget — the load shift that makes online
    /// re-planning earn its keep.
    Ramp { start_fps: f64, end_fps: f64 },
}

impl ArrivalProcess {
    fn validate(&self) -> Result<()> {
        let bad = |what: &str| Err(Error::Config(format!("arrival process: {what}")));
        match self {
            ArrivalProcess::Poisson { rate_fps } if *rate_fps <= 0.0 => {
                bad("poisson rate_fps must be > 0")
            }
            ArrivalProcess::Burst {
                burst_fps,
                burst_len,
                idle_seconds,
            } if *burst_fps <= 0.0 || *burst_len == 0 || *idle_seconds < 0.0 => {
                bad("burst needs burst_fps > 0, burst_len > 0, idle_seconds >= 0")
            }
            ArrivalProcess::Ramp { start_fps, end_fps }
                if *start_fps <= 0.0 || *end_fps <= 0.0 =>
            {
                bad("ramp rates must be > 0")
            }
            _ => Ok(()),
        }
    }
}

/// One synthetic client stream.
#[derive(Debug, Clone)]
pub struct ClientSpec {
    /// Display name (reports).
    pub name: String,
    /// Index into the serve options' QoS class table.
    pub class: usize,
    /// Total frames this client will offer (its budget).
    pub frames: usize,
    pub arrivals: ArrivalProcess,
}

impl ClientSpec {
    pub fn new(name: impl Into<String>, frames: usize, arrivals: ArrivalProcess) -> Self {
        ClientSpec {
            name: name.into(),
            class: 0,
            frames,
            arrivals,
        }
    }

    /// Assign the QoS class (index into [`crate::serve::ServeOptions`]'s
    /// class table).
    pub fn qos_class(mut self, class: usize) -> Self {
        self.class = class;
        self
    }
}

/// One offered frame: model-time arrival, owning client, and the frame's
/// sequence number within that client.
#[derive(Debug, Clone, Copy)]
pub struct Arrival {
    /// Model seconds since serve start.
    pub t: f64,
    /// Index into the client table.
    pub client: usize,
    /// 0-based sequence within the client's budget.
    pub seq: u64,
}

/// Expand every client's arrival process and merge into one time-ordered
/// schedule. Deterministic: same clients + seed ⇒ identical schedule.
pub fn schedule(clients: &[ClientSpec], seed: u64) -> Result<Vec<Arrival>> {
    if clients.is_empty() {
        return Err(Error::Config("serve needs at least one client stream".into()));
    }
    let mut all = Vec::new();
    for (ci, c) in clients.iter().enumerate() {
        c.arrivals.validate()?;
        if c.frames == 0 {
            return Err(Error::Config(format!(
                "client `{}` has a zero frame budget",
                c.name
            )));
        }
        let mut rng = Rng::new(seed ^ (ci as u64).wrapping_mul(0xA076_1D64_78BD_642F));
        let mut t = 0.0f64;
        for seq in 0..c.frames {
            match &c.arrivals {
                ArrivalProcess::Poisson { rate_fps } => {
                    // exponential gap; max() guards ln(0)
                    let u = rng.next_f64().max(f64::MIN_POSITIVE);
                    t += -u.ln() / rate_fps;
                }
                ArrivalProcess::Burst {
                    burst_fps,
                    burst_len,
                    idle_seconds,
                } => {
                    if seq > 0 && seq % burst_len == 0 {
                        t += idle_seconds;
                    } else if seq > 0 {
                        t += 1.0 / burst_fps;
                    }
                }
                ArrivalProcess::Ramp { start_fps, end_fps } => {
                    let frac = seq as f64 / c.frames.max(1) as f64;
                    let rate = start_fps + (end_fps - start_fps) * frac;
                    t += 1.0 / rate;
                }
            }
            all.push(Arrival {
                t,
                client: ci,
                seq: seq as u64,
            });
        }
    }
    // Stable order: time, then client index for simultaneous arrivals.
    all.sort_by(|a, b| {
        a.t.total_cmp(&b.t)
            .then(a.client.cmp(&b.client))
            .then(a.seq.cmp(&b.seq))
    });
    Ok(all)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_merged_in_time_order() {
        let clients = vec![
            ClientSpec::new("a", 50, ArrivalProcess::Poisson { rate_fps: 100.0 }),
            ClientSpec::new("b", 30, ArrivalProcess::Poisson { rate_fps: 60.0 }),
        ];
        let s1 = schedule(&clients, 7).unwrap();
        let s2 = schedule(&clients, 7).unwrap();
        assert_eq!(s1.len(), 80);
        for (x, y) in s1.iter().zip(s2.iter()) {
            assert_eq!((x.t, x.client, x.seq), (y.t, y.client, y.seq));
        }
        for w in s1.windows(2) {
            assert!(w[1].t >= w[0].t);
        }
        // per-client sequence numbers stay ordered after the merge
        let a_seqs: Vec<u64> = s1.iter().filter(|a| a.client == 0).map(|a| a.seq).collect();
        assert_eq!(a_seqs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn poisson_mean_rate_is_approximately_nominal() {
        let clients = vec![ClientSpec::new(
            "p",
            2000,
            ArrivalProcess::Poisson { rate_fps: 200.0 },
        )];
        let s = schedule(&clients, 11).unwrap();
        let span = s.last().unwrap().t;
        let rate = 2000.0 / span;
        assert!((120.0..320.0).contains(&rate), "observed rate {rate}");
    }

    #[test]
    fn burst_inserts_idle_gaps() {
        let clients = vec![ClientSpec::new(
            "b",
            64,
            ArrivalProcess::Burst {
                burst_fps: 1000.0,
                burst_len: 16,
                idle_seconds: 0.5,
            },
        )];
        let s = schedule(&clients, 1).unwrap();
        let gaps: Vec<f64> = s.windows(2).map(|w| w[1].t - w[0].t).collect();
        let idles = gaps.iter().filter(|&&g| g > 0.4).count();
        assert_eq!(idles, 3, "64 frames in 16-bursts have 3 inter-burst idles");
    }

    #[test]
    fn ramp_intervals_shrink_toward_the_end() {
        let clients = vec![ClientSpec::new(
            "r",
            100,
            ArrivalProcess::Ramp {
                start_fps: 50.0,
                end_fps: 500.0,
            },
        )];
        let s = schedule(&clients, 1).unwrap();
        let first_gap = s[1].t - s[0].t;
        let last_gap = s[99].t - s[98].t;
        assert!(
            last_gap < first_gap / 4.0,
            "ramp must accelerate: first {first_gap}, last {last_gap}"
        );
    }

    #[test]
    fn invalid_shapes_rejected() {
        assert!(schedule(&[], 0).is_err());
        let zero_rate = vec![ClientSpec::new(
            "z",
            4,
            ArrivalProcess::Poisson { rate_fps: 0.0 },
        )];
        assert!(schedule(&zero_rate, 0).is_err());
        let zero_budget = vec![ClientSpec::new(
            "z",
            0,
            ArrivalProcess::Poisson { rate_fps: 10.0 },
        )];
        assert!(schedule(&zero_budget, 0).is_err());
    }
}
