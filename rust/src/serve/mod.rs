//! The long-running serving front-end.
//!
//! [`crate::session::Session::run`] is a *batch* run: stream a fixed
//! frame count, drain, exit. This module is the paper's actual
//! deployment shape — an open-ended loop fed by concurrent synthetic
//! client streams ([`clients`]), guarded by per-class QoS admission
//! control ([`admission`]), observed through rolling telemetry windows
//! ([`telemetry`]), and **re-planned online** ([`replan`]): when the
//! windows show engines idling while load builds, the placement search
//! runs against the observed workload and the pipeline switches to the
//! winning spec at a frame boundary via a drain-and-switch handoff —
//! the old [`StreamCore`](crate::pipeline::driver::StreamCore) completes
//! every admitted frame before the new one takes over, so nothing is
//! lost and per-client frame order is preserved.
//!
//! ```no_run
//! use edgepipe::dla::DlaVersion;
//! use edgepipe::hw;
//! use edgepipe::pipeline::SimBackend;
//! use edgepipe::serve::{self, ArrivalProcess, ClientSpec, ServeOptions};
//! use edgepipe::session::Session;
//! use std::sync::Arc;
//!
//! let session = Session::builder()
//!     .workload(edgepipe::config::Workload::TwoGans, edgepipe::config::GanVariant::Cropping)
//!     .backend(Arc::new(SimBackend::new(hw::orin()).with_time_scale(0.05)))
//!     .build()?;
//! let mut opts = ServeOptions::new(hw::orin(), DlaVersion::V2);
//! opts.time_scale = 0.05;
//! opts.clients = vec![ClientSpec::new(
//!     "hospital-a",
//!     512,
//!     ArrivalProcess::Ramp { start_fps: 60.0, end_fps: 400.0 },
//! )];
//! let report = serve::serve(session, opts)?;
//! println!("{} re-plan(s), p99 {:.1} ms", report.replans.len(), report.latency_ms_p99);
//! # Ok::<(), edgepipe::Error>(())
//! ```


// Serving hot path: no unwraps outside tests (see util::lock::relock).
#![deny(clippy::unwrap_used)]
pub mod admission;
pub mod clients;
pub mod replan;
pub mod telemetry;

pub use admission::{AdmissionController, ClassStats, QosClass, ShedReason};
pub use clients::{Arrival, ArrivalProcess, ClientSpec};
pub use replan::{ReplanEvent, ReplanPolicy, Replanner};
pub use telemetry::{Completion, Telemetry, WindowStats};

use crate::config::json::{arr, num, obj, s, Json};
use crate::dla::DlaVersion;
use crate::error::{Error, Result};
use crate::hw::SocSpec;
use crate::obs::registry::{Counter, Gauge, Histogram, Registry};
use crate::obs::{ObsEvent, ObsHub, StageBreakdown};
use crate::pipeline::driver::{CompletionSink, PipelineReport, StreamCore};
use crate::pipeline::plane::PlanePool;
use crate::pipeline::source::{FrameSource, ReconReport, ReconStats};
use crate::pipeline::spec::{PipelineSpec, SourceSpec};
use crate::placement::score::primary_instances;
use crate::session::Session;
use crate::sim::timeline::{Span, Timeline};
use replan::spec_key;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// Everything the serve loop needs beyond the session's spec + backend.
#[derive(Clone)]
pub struct ServeOptions {
    /// Device model used by the re-planner's virtual-time scoring (match
    /// the backend's SoC).
    pub soc: SocSpec,
    pub dla_version: DlaVersion,
    /// Client streams (at least one).
    pub clients: Vec<ClientSpec>,
    /// QoS class table; each client's `class` indexes into it.
    pub qos: Vec<QosClass>,
    pub replan: ReplanPolicy,
    /// Wall seconds per model second of the arrival schedule. Match the
    /// sim backend's `time_scale` to fast-forward a load profile; `0.0`
    /// disables pacing (arrivals bound only by backpressure).
    pub time_scale: f64,
    pub seed: u64,
    /// Retained completion-event tail (windows + optional record).
    pub telemetry_capacity: usize,
    /// Span cap on the merged serving timeline in the report. An
    /// open-ended serve records spans per dispatch per unit; beyond this
    /// many, further phase spans are dropped (switch markers are always
    /// kept) and the report flags the truncation.
    pub timeline_capacity: usize,
    /// Observability hub (`None` = untraced, zero overhead). When set,
    /// the serve loop registers its admission counters/gauges and
    /// completion histogram into the hub's registry, folds every frame's
    /// stage stamps into the hub's accumulator, takes a
    /// checkpoint-aligned registry snapshot, and logs replan/shed-burst
    /// events — `--trace-out`/`--metrics-out` hang off this.
    pub obs: Option<Arc<ObsHub>>,
}

impl ServeOptions {
    pub fn new(soc: SocSpec, dla_version: DlaVersion) -> ServeOptions {
        ServeOptions {
            soc,
            dla_version,
            clients: Vec::new(),
            qos: vec![QosClass::unlimited("default", 0)],
            replan: ReplanPolicy::default(),
            time_scale: 1.0,
            seed: 0xED6E,
            telemetry_capacity: 1 << 16,
            timeline_capacity: 100_000,
            obs: None,
        }
    }
}

/// One spec's tenure between drain-and-switch boundaries.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    pub spec_key: String,
    /// Serve-clock second the phase's core came up.
    pub start_seconds: f64,
    /// Unique frames completed in this phase (primary-path count).
    pub completed: usize,
    pub report: PipelineReport,
}

/// The serve loop's full account.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Frames presented to admission (every scheduled arrival).
    pub offered: usize,
    /// Frames accepted into the pipeline (`offered - shed`).
    pub accepted: usize,
    /// Unique frames completed on lossless paths. Conservation:
    /// `offered == completed + shed` (and `accepted == completed`) —
    /// drain-and-switch loses nothing.
    pub completed: usize,
    /// Frames refused by admission control.
    pub shed: usize,
    pub shed_rate_limit: usize,
    pub shed_deadline: usize,
    /// Droppable fanout copies discarded on overload across every phase
    /// (the pipelines' `dropped` ledger — distinct from `shed`; unique
    /// lossless frames are unaffected).
    pub dropped: usize,
    /// Whole-run latency percentiles, milliseconds.
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    pub wall_seconds: f64,
    pub windows: Vec<WindowStats>,
    pub replans: Vec<ReplanEvent>,
    pub phases: Vec<PhaseReport>,
    /// Merged serving timeline on the serve clock: every phase's engine
    /// spans plus one zero-width transition marker per unit at each
    /// drain-and-switch boundary. Bounded by
    /// [`ServeOptions::timeline_capacity`].
    pub timeline: Timeline,
    /// Phase spans were dropped because the merged timeline hit its cap
    /// (markers are always kept).
    pub timeline_truncated: bool,
    /// Per-class admission outcomes.
    pub classes: Vec<(QosClass, ClassStats)>,
    /// Completion event tail (bounded by `telemetry_capacity`) — what the
    /// ordering/conservation property tests inspect.
    pub completions: Vec<Completion>,
    /// Frame-lifecycle stage latency breakdown across every phase,
    /// present only when [`ServeOptions::obs`] was set.
    pub stages: Option<StageBreakdown>,
    /// K-space recon front-end summary across the whole serve (all
    /// phases), present only when the source is `kspace`.
    pub recon: Option<ReconReport>,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("offered", num(self.offered as f64)),
            ("accepted", num(self.accepted as f64)),
            ("completed", num(self.completed as f64)),
            ("shed", num(self.shed as f64)),
            ("shed_rate_limit", num(self.shed_rate_limit as f64)),
            ("shed_deadline", num(self.shed_deadline as f64)),
            ("dropped", num(self.dropped as f64)),
            ("latency_ms_p50", num(self.latency_ms_p50)),
            ("latency_ms_p95", num(self.latency_ms_p95)),
            ("latency_ms_p99", num(self.latency_ms_p99)),
            ("wall_seconds", num(self.wall_seconds)),
            ("replans", arr(self.replans.iter().map(|r| r.to_json()).collect())),
            ("windows", arr(self.windows.iter().map(|w| w.to_json()).collect())),
            (
                "classes",
                arr(self
                    .classes
                    .iter()
                    .map(|(c, st)| admission::class_row(c, st))
                    .collect()),
            ),
            (
                "phases",
                arr(self
                    .phases
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("spec", s(&p.spec_key)),
                            ("start_seconds", num(p.start_seconds)),
                            ("completed", num(p.completed as f64)),
                            ("report", p.report.to_json()),
                        ])
                    })
                    .collect()),
            ),
            ("timeline_spans", num(self.timeline.spans.len() as f64)),
            ("timeline_truncated", Json::Bool(self.timeline_truncated)),
            (
                "switch_markers",
                num(self
                    .timeline
                    .spans
                    .iter()
                    .filter(|sp| sp.t0 == sp.t1 && sp.is_transition)
                    .count() as f64),
            ),
        ];
        if let Some(st) = &self.stages {
            pairs.push(("stages", st.to_json()));
        }
        if let Some(r) = &self.recon {
            pairs.push(("recon", r.to_json()));
        }
        obj(pairs)
    }
}

/// Registry handles for the serve loop's admission-side series
/// (registered once; the per-arrival path pays one relaxed atomic op per
/// event).
struct ServeMeters {
    offered: Arc<Counter>,
    accepted: Arc<Counter>,
    shed: Arc<Counter>,
    shed_rate_limit: Arc<Counter>,
    shed_deadline: Arc<Counter>,
    backlog: Arc<Gauge>,
    est_wait: Arc<Gauge>,
    dropped: Arc<Gauge>,
}

impl ServeMeters {
    fn register(reg: &Registry) -> ServeMeters {
        ServeMeters {
            offered: reg.counter("serve_offered_total", "frames presented to admission"),
            accepted: reg.counter("serve_accepted_total", "frames admitted into the pipeline"),
            shed: reg.counter("serve_shed_total", "frames refused by admission control"),
            shed_rate_limit: reg.counter(
                "serve_shed_rate_limit_total",
                "sheds from an empty class token bucket",
            ),
            shed_deadline: reg.counter(
                "serve_shed_deadline_total",
                "sheds from a blown class deadline",
            ),
            backlog: reg.gauge(
                "serve_backlog_frames",
                "admitted unique frames not yet completed (checkpoint read)",
            ),
            est_wait: reg.gauge(
                "serve_est_wait_ms",
                "estimated queueing delay fed to deadline shedding, model-time ms",
            ),
            dropped: reg.gauge(
                "serve_dropped_copies",
                "droppable fanout copies discarded on overload, cumulative",
            ),
        }
    }
}

/// [`Telemetry`] completion-sink wrapper that mirrors every completion
/// into the metrics registry: one counter bump plus one O(1) histogram
/// record per frame copy on top of the telemetry ring push.
struct MeteredSink {
    inner: Arc<Telemetry>,
    n_completed: Arc<Counter>,
    lat_hist: Arc<Histogram>,
}

impl CompletionSink for MeteredSink {
    fn completed(&self, instance: usize, stream: usize, frame_id: u64, latency_s: f64) {
        self.inner.completed(instance, stream, frame_id, latency_s);
        self.n_completed.inc();
        self.lat_hist.record(latency_s);
    }
}

/// Unique frames completed by a core so far (its primary-path count).
fn primary_completed(completed: &[usize], spec: &PipelineSpec) -> usize {
    let primary = primary_instances(spec.route, spec.instances.len());
    completed
        .iter()
        .zip(primary.iter())
        .filter(|(_, p)| **p)
        .map(|(n, _)| n)
        .sum()
}

/// Run the serve loop to the end of every client's budget. The session
/// provides the initial spec and the backend; `opts` provides the load,
/// the QoS policy, and the re-planning policy.
pub fn serve(session: Session, opts: ServeOptions) -> Result<ServeReport> {
    let (mut spec, backend) = session.into_parts();
    let schedule = clients::schedule(&opts.clients, opts.seed)?;
    for c in &opts.clients {
        if c.class >= opts.qos.len() {
            return Err(Error::Config(format!(
                "client `{}` names QoS class {} but only {} class(es) are defined",
                c.name,
                c.class,
                opts.qos.len()
            )));
        }
    }
    let mut admission = AdmissionController::new(opts.qos.clone())?;
    let mut replanner = Replanner::new(opts.replan.clone(), opts.soc.clone(), opts.dla_version);
    let telemetry = Arc::new(Telemetry::new(opts.telemetry_capacity));
    let hub = opts.obs.clone();
    let stages = hub.as_ref().map(|h| Arc::clone(&h.stages));
    let meters = hub.as_ref().map(|h| ServeMeters::register(&h.registry));
    let sink: Arc<dyn CompletionSink> = match &hub {
        Some(h) => Arc::new(MeteredSink {
            inner: Arc::clone(&telemetry),
            n_completed: h.registry.counter(
                "serve_completed_total",
                "frame copies completed across all instances",
            ),
            lat_hist: h.registry.histogram(
                "serve_latency_seconds",
                "admission-to-completion latency per frame copy",
            ),
        }),
        None => Arc::clone(&telemetry) as Arc<dyn CompletionSink>,
    };

    // One plane pool across all clients and all phases: drained frames
    // park their buffers for the next arrivals regardless of spec swaps.
    // Likewise one recon accumulator (kspace sources only) — the source
    // mode survives spec swaps, so its stats span the whole serve.
    let pool = PlanePool::default();
    let recon_stats = match &spec.source {
        SourceSpec::Kspace { .. } => Some(Arc::new(ReconStats::default())),
        SourceSpec::Phantom => None,
    };
    let mut sources: Vec<FrameSource> = opts
        .clients
        .iter()
        .enumerate()
        .map(|(i, c)| {
            FrameSource::for_spec(
                &spec.source,
                opts.seed,
                i,
                c.frames,
                pool.clone(),
                recon_stats.clone(),
            )
        })
        .collect::<Result<Vec<_>>>()?;

    let check_every = replanner.policy().check_every_frames.max(1);
    let mut core = StreamCore::new(&spec, &backend, Some(Arc::clone(&sink)), stages.clone())?;
    // The primary-instance mask only changes on a spec swap; caching it
    // keeps the per-checkpoint backlog read allocation-free.
    let mut primary_mask = primary_instances(spec.route, spec.instances.len());
    let mut phase_started = telemetry.now();
    let mut phase_offset = phase_started - core.arbiter().clock_seconds();
    // Incremental checkpoint reads: spans already inspected are never
    // re-cloned (an open-ended serve would otherwise go quadratic).
    let mut span_cursor = 0usize;
    // Same for completions: each checkpoint pulls only the events it has
    // not seen yet into a locally capped tail, so windowed stats and the
    // report record never re-clone the telemetry ring.
    let mut comp_cursor = 0usize;
    let mut comp_tail: VecDeque<Completion> = VecDeque::new();

    let mut timeline = Timeline::default();
    let mut timeline_truncated = false;
    // Append a drained phase's spans to the merged serve-clock timeline,
    // bounded by the configured cap.
    let merge_phase_timeline = |timeline: &mut Timeline,
                                    truncated: &mut bool,
                                    phase: &Timeline,
                                    offset: f64| {
        for sp in &phase.spans {
            if timeline.spans.len() >= opts.timeline_capacity {
                *truncated = true;
                break;
            }
            timeline.push(Span {
                t0: sp.t0 + offset,
                t1: sp.t1 + offset,
                ..sp.clone()
            });
        }
    };
    let mut phases: Vec<PhaseReport> = Vec::new();
    let mut replans: Vec<ReplanEvent> = Vec::new();
    let mut windows: Vec<WindowStats> = Vec::new();
    let mut offered = 0usize;
    let mut accepted = 0usize;
    let mut completed_prev_phases = 0usize;
    // Window bookkeeping (serve clock + model clock).
    let mut win_t0 = telemetry.now();
    let mut win_offered = 0usize;
    let mut win_shed_base = 0usize;
    let mut win_dropped_base = 0usize;
    let mut dropped_prev_phases = 0usize;
    let mut win_arrival_t0 = 0.0f64;
    // Deadline-aware shedding input: max(recent p95 latency, backlog /
    // served rate), refreshed at every checkpoint.
    let mut est_wait_ms = 0.0f64;

    // Closes the current window; returns the stats (also pushed). Window
    // stats come from the locally pulled completion tail, not a telemetry
    // ring scan — callers pull `completions_since` first.
    let close_window = |windows: &mut Vec<WindowStats>,
                        tail: &VecDeque<Completion>,
                        tl_busy: Vec<(String, f64)>,
                        t0: f64,
                        t1: f64,
                        offered_in: usize,
                        shed_in: usize,
                        dropped_in: usize,
                        arrival_span: f64|
     -> WindowStats {
        let (completed_w, lat) = telemetry::window_from_tail(tail, t0, t1);
        let width = (t1 - t0).max(f64::MIN_POSITIVE);
        let ws = WindowStats {
            t0,
            t1,
            completed: completed_w,
            fps: completed_w as f64 / width,
            latency_ms_p50: lat.p50() * 1e3,
            latency_ms_p95: lat.percentile(95.0) * 1e3,
            latency_ms_p99: lat.p99() * 1e3,
            offered: offered_in,
            shed: shed_in,
            dropped: dropped_in,
            arrival_fps: offered_in as f64 / arrival_span.max(f64::MIN_POSITIVE),
            engine_busy: tl_busy,
        };
        windows.push(ws.clone());
        ws
    };

    let mut primary_died = false;
    'serve: for a in &schedule {
        // Pace to the (time-scaled) arrival schedule.
        if opts.time_scale > 0.0 {
            let target = a.t * opts.time_scale;
            let now = telemetry.now();
            if target > now {
                std::thread::sleep(Duration::from_secs_f64(target - now));
            }
        }
        offered += 1;
        win_offered += 1;
        if let Some(m) = &meters {
            m.offered.inc();
        }

        let class = opts.clients[a.client].class;
        match admission.decide(class, a.t, est_wait_ms) {
            Some(reason) => {
                core.record_shed();
                if let Some(m) = &meters {
                    m.shed.inc();
                    match reason {
                        ShedReason::RateLimit => m.shed_rate_limit.inc(),
                        ShedReason::Deadline => m.shed_deadline.inc(),
                    }
                }
            }
            None => {
                // The arrival schedule is built from the same per-client
                // budgets the sources enforce, so a missing frame is
                // unreachable; an expect beats silently losing an
                // admitted frame.
                let frame = sources[a.client]
                    .next()
                    // lint:allow(panic-freedom) — unreachable by schedule construction
                    .expect("schedule never exceeds a client's budget");
                accepted += 1;
                if let Some(m) = &meters {
                    m.accepted.inc();
                }
                if !core.submit(frame) {
                    primary_died = true;
                    break 'serve;
                }
            }
        }

        // Checkpoint: close the telemetry window, maybe re-plan.
        if offered % check_every == 0 {
            let now = telemetry.now();
            // Spans land at dispatch completion, so the tail since the
            // last read covers everything overlapping this window.
            let tail = Timeline {
                spans: core.arbiter().spans_from(span_cursor),
            };
            span_cursor += tail.spans.len();
            comp_cursor = telemetry.completions_since(comp_cursor, &mut comp_tail);
            while comp_tail.len() > opts.telemetry_capacity {
                comp_tail.pop_front();
            }
            let busy = telemetry::engine_busy_in_window(&tail, phase_offset, win_t0, now);
            let shed_now = admission.shed_total();
            let dropped_now = dropped_prev_phases + core.dropped_so_far();
            let ws = close_window(
                &mut windows,
                &comp_tail,
                busy,
                win_t0,
                now,
                win_offered,
                shed_now - win_shed_base,
                dropped_now - win_dropped_base,
                a.t - win_arrival_t0,
            );
            win_t0 = now;
            win_offered = 0;
            win_shed_base = shed_now;
            win_dropped_base = dropped_now;
            win_arrival_t0 = a.t;

            // Backlog (unique frames) + wait estimate for deadline sheds.
            let phase_primary = core.primary_completed(&primary_mask);
            let backlog = core.submitted().saturating_sub(phase_primary);
            let copies = spec.route.copies_per_frame(spec.instances.len());
            let unique_fps = ws.fps / copies as f64;
            let backlog_wait_ms = if unique_fps > 0.0 {
                backlog as f64 / unique_fps * 1e3
            } else {
                0.0
            };
            // Deadlines are *model-time* milliseconds: convert the
            // wall-clock estimate so a fast-forwarded sim run sheds the
            // same frames a real-time run would.
            let wall_to_model = if opts.time_scale > 0.0 {
                1.0 / opts.time_scale
            } else {
                1.0
            };
            est_wait_ms = if ws.completed > 0 {
                ws.latency_ms_p95.max(backlog_wait_ms) * wall_to_model
            } else {
                backlog_wait_ms * wall_to_model
            };

            // Checkpoint-aligned observability: refresh the gauges, log a
            // shed burst if this window refused anything, snapshot the
            // registry.
            if let Some(h) = &hub {
                if let Some(m) = &meters {
                    m.backlog.set(backlog as f64);
                    m.est_wait.set(est_wait_ms);
                    m.dropped.set(dropped_now as f64);
                }
                if ws.shed > 0 {
                    h.push_event(ObsEvent::shed_burst(
                        now,
                        None,
                        format!("shed {} of {} offered", ws.shed, ws.offered),
                        ws.to_json(),
                    ));
                }
                h.snapshot_at(now);
            }

            if let Some(prop) = replanner.consider(&spec, &ws, backlog)? {
                // ---- drain-and-switch ----
                let mut report = core.finish()?; // every admitted frame lands
                merge_phase_timeline(
                    &mut timeline,
                    &mut timeline_truncated,
                    &report.timeline,
                    phase_offset,
                );
                // The drain itself can take a while under backlog; those
                // completions belong to the OLD spec and must not fall in
                // a window gap — close a drain window over [checkpoint,
                // drain end] when anything completed in it.
                let t_drained = telemetry.now();
                comp_cursor = telemetry.completions_since(comp_cursor, &mut comp_tail);
                while comp_tail.len() > opts.telemetry_capacity {
                    comp_tail.pop_front();
                }
                if telemetry::window_from_tail(&comp_tail, win_t0, t_drained).0 > 0 {
                    let drain_busy = telemetry::engine_busy_in_window(
                        &report.timeline,
                        phase_offset,
                        win_t0,
                        t_drained,
                    );
                    close_window(
                        &mut windows,
                        &comp_tail,
                        drain_busy,
                        win_t0,
                        t_drained,
                        0,
                        0,
                        // copies discarded while the old core drained
                        (dropped_prev_phases + report.dropped)
                            .saturating_sub(win_dropped_base),
                        0.0,
                    );
                }
                let phase_completed = primary_completed(
                    &report.instances.iter().map(|i| i.frames).collect::<Vec<_>>(),
                    &spec,
                );
                completed_prev_phases += phase_completed;
                dropped_prev_phases += report.dropped;
                // the new core's counter starts at zero; windows resume
                // from the cumulative phase total
                win_dropped_base = dropped_prev_phases;
                // The phase's spans now live (bounded) in the merged
                // timeline; retaining them twice would double memory.
                report.timeline = Timeline::default();
                phases.push(PhaseReport {
                    spec_key: spec_key(&spec),
                    start_seconds: phase_started,
                    completed: phase_completed,
                    report,
                });

                let t_switch = telemetry.now();
                // Zero-width transition markers record the handoff on
                // every unit's timeline row.
                for (kind, unit) in telemetry::soc_units() {
                    timeline.push(Span {
                        engine: kind,
                        unit,
                        instance: 0,
                        frame: offered,
                        t0: t_switch,
                        t1: t_switch,
                        is_transition: true,
                    });
                }
                // Graft the serve's stream shape onto the planned spec.
                // The acquisition source rides along: a replan changes
                // placement, never what the clients are sending.
                let mut next = prop.spec;
                next.frames = spec.frames;
                next.streams = spec.streams;
                next.queue_depth = spec.queue_depth;
                next.seed = spec.seed;
                next.source = spec.source.clone();
                replans.push(ReplanEvent {
                    at_frame: offered,
                    at_seconds: t_switch,
                    from_key: spec_key(&spec),
                    to_key: spec_key(&next),
                    predicted_fps_before: prop.predicted_fps_before,
                    predicted_fps_after: prop.predicted_fps_after,
                    reason: prop.reason,
                });
                if let (Some(h), Some(ev)) = (&hub, replans.last()) {
                    h.push_event(ObsEvent::replan(
                        ev.at_seconds,
                        format!("{} -> {}", ev.from_key, ev.to_key),
                        ev.to_json(),
                    ));
                }
                spec = next;
                core = StreamCore::new(&spec, &backend, Some(Arc::clone(&sink)), stages.clone())?;
                primary_mask = primary_instances(spec.route, spec.instances.len());
                phase_started = telemetry.now();
                phase_offset = phase_started - core.arbiter().clock_seconds();
                span_cursor = 0;
                win_t0 = phase_started;
            }
        }
    }

    // Final drain (also where a dead primary worker's error surfaces).
    let final_report = core.finish();
    if primary_died {
        // The worker's own error is the interesting one; a clean join
        // despite a dead primary would be a coordinator bug.
        return Err(final_report.err().unwrap_or_else(|| {
            Error::Pipeline("primary worker queue closed without a worker error".into())
        }));
    }
    let mut report = final_report?;
    merge_phase_timeline(
        &mut timeline,
        &mut timeline_truncated,
        &report.timeline,
        phase_offset,
    );
    let phase_completed = primary_completed(
        &report.instances.iter().map(|i| i.frames).collect::<Vec<_>>(),
        &spec,
    );
    let completed = completed_prev_phases + phase_completed;
    report.timeline = Timeline::default();
    phases.push(PhaseReport {
        spec_key: spec_key(&spec),
        start_seconds: phase_started,
        completed: phase_completed,
        report,
    });

    // Tail window over the drain (merged timeline is already serve-clock).
    let end = telemetry.now();
    let shed_total = admission.shed_total();
    let dropped_total = dropped_prev_phases + phases.last().map(|p| p.report.dropped).unwrap_or(0);
    let _ = telemetry.completions_since(comp_cursor, &mut comp_tail);
    while comp_tail.len() > opts.telemetry_capacity {
        comp_tail.pop_front();
    }
    let busy = telemetry::engine_busy_in_window(&timeline, 0.0, win_t0, end);
    close_window(
        &mut windows,
        &comp_tail,
        busy,
        win_t0,
        end,
        win_offered,
        shed_total - win_shed_base,
        dropped_total.saturating_sub(win_dropped_base),
        schedule.last().map(|a| a.t - win_arrival_t0).unwrap_or(0.0),
    );

    // Final registry state: the closing snapshot an open-ended consumer
    // would otherwise miss (gauges settle to their drained values).
    if let Some(h) = &hub {
        if let Some(m) = &meters {
            m.backlog.set(0.0);
            m.dropped.set(dropped_total as f64);
        }
        h.snapshot_at(end);
    }

    debug_assert_eq!(offered, accepted + shed_total);
    Ok(ServeReport {
        offered,
        accepted,
        completed,
        shed: shed_total,
        shed_rate_limit: admission.stats().iter().map(|s| s.shed_rate_limit).sum(),
        shed_deadline: admission.stats().iter().map(|s| s.shed_deadline).sum(),
        dropped: dropped_total,
        latency_ms_p50: telemetry.latency_ms_percentile(50.0),
        latency_ms_p95: telemetry.latency_ms_percentile(95.0),
        latency_ms_p99: telemetry.latency_ms_percentile(99.0),
        wall_seconds: end,
        windows,
        replans,
        phases,
        timeline,
        timeline_truncated,
        classes: opts
            .qos
            .iter()
            .cloned()
            .zip(admission.stats().iter().cloned())
            .collect(),
        completions: comp_tail.into_iter().collect(),
        stages: stages.map(|acc| acc.breakdown()),
        recon: recon_stats.and_then(|st| st.report(&spec.source)),
    })
}
