//! Online re-planning: make the placement planner load-bearing at
//! *runtime*, not just at plan time.
//!
//! A [`Replanner`] is consulted at frame-boundary checkpoints with the
//! latest telemetry window. When the window shows exploitable slack or
//! distress — engines sitting idle while a backlog builds, or offered
//! load outrunning served throughput — it re-invokes the
//! [`crate::placement`] search *against the observed load profile*
//! ([`PlacementRequest::for_spec`] keeps the workload shape, widening the
//! batch axis under backlog) and proposes a switch when the best
//! candidate's predicted FPS beats the current spec's by at least
//! `min_gain`. The serve loop then performs the drain-and-switch handoff:
//! the old core drains every admitted frame, the new core takes over at
//! the next frame boundary, and the [`ReplanEvent`] is recorded in both
//! the report and the merged serving timeline.

// Checkpoint controller on the serve loop.
#![deny(clippy::unwrap_used)]

use crate::config::json::{num, obj, s, Json};
use crate::dla::DlaVersion;
use crate::error::Result;
use crate::hw::SocSpec;
use crate::pipeline::spec::PipelineSpec;
use crate::placement::{self, PlacementRequest};

use super::telemetry::WindowStats;

/// When and how eagerly to re-plan.
#[derive(Debug, Clone)]
pub struct ReplanPolicy {
    pub enabled: bool,
    /// Offered frames between checkpoints (also the telemetry window).
    pub check_every_frames: usize,
    /// Fractional predicted-FPS gain required to switch (0.10 = 10%).
    pub min_gain: f64,
    /// Mean unit idle fraction above which the search is (re)triggered.
    pub idle_frac_threshold: f64,
    /// Checkpoints to sit out after a switch (let the new spec settle).
    pub cooldown_checks: usize,
    /// Test/bench hook: unconditionally drain-and-switch every N
    /// checkpoints (to the *same* spec when planning finds nothing
    /// better), exercising the handoff machinery without load shaping.
    pub force_every_checks: Option<usize>,
}

impl Default for ReplanPolicy {
    fn default() -> Self {
        ReplanPolicy {
            enabled: true,
            check_every_frames: 256,
            min_gain: 0.10,
            idle_frac_threshold: 0.30,
            cooldown_checks: 1,
            force_every_checks: None,
        }
    }
}

impl ReplanPolicy {
    pub fn disabled() -> Self {
        ReplanPolicy {
            enabled: false,
            ..ReplanPolicy::default()
        }
    }
}

/// One executed drain-and-switch, for the report and the timeline.
#[derive(Debug, Clone)]
pub struct ReplanEvent {
    /// Offered-frame count at the switch boundary.
    pub at_frame: usize,
    /// Serve-clock seconds at the switch.
    pub at_seconds: f64,
    pub from_key: String,
    pub to_key: String,
    /// Virtual-time predicted FPS of the outgoing spec.
    pub predicted_fps_before: f64,
    /// Predicted FPS of the incoming spec.
    pub predicted_fps_after: f64,
    /// Trigger description (`idle 0.62 >= 0.30`, `forced`, ...).
    pub reason: String,
}

impl ReplanEvent {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("at_frame", num(self.at_frame as f64)),
            ("at_seconds", num(self.at_seconds)),
            ("from", s(&self.from_key)),
            ("to", s(&self.to_key)),
            ("predicted_fps_before", num(self.predicted_fps_before)),
            ("predicted_fps_after", num(self.predicted_fps_after)),
            ("reason", s(&self.reason)),
        ])
    }
}

/// Identity of a spec's *placement-relevant* shape: what runs where with
/// what batching under which route. Stream shape (frames/seed/depth) is
/// excluded — the serve loop carries it across switches unchanged.
pub fn spec_key(spec: &PipelineSpec) -> String {
    let mut parts: Vec<String> = spec
        .instances
        .iter()
        .map(|i| {
            format!(
                "{}@{}x{}",
                i.artifact,
                i.engine.unit_label(i.engine_index),
                i.batch.max_batch
            )
        })
        .collect();
    parts.sort();
    format!("{}|{}", spec.route.name(), parts.join("+"))
}

/// A proposed switch: the new spec (stream shape NOT yet grafted) plus
/// the event skeleton.
pub struct Proposal {
    pub spec: PipelineSpec,
    pub predicted_fps_before: f64,
    pub predicted_fps_after: f64,
    pub reason: String,
}

/// The controller. One per serve; consulted at checkpoints.
pub struct Replanner {
    policy: ReplanPolicy,
    soc: SocSpec,
    dla_version: DlaVersion,
    checks: usize,
    cooldown: usize,
    /// Spec key a search already failed to improve on. Structural idle
    /// (a GAN-only spec always leaves the GPU cold) would otherwise pay
    /// a full placement search every checkpoint forever; while the spec
    /// is settled, only a materially *worse* backlog re-opens the search.
    settled_key: Option<String>,
    /// Backlog observed when the spec settled — sustained overload at a
    /// steady backlog (backpressure plateaus it) must not re-run the
    /// search every checkpoint on the admission thread.
    settled_backlog: usize,
}

impl Replanner {
    pub fn new(policy: ReplanPolicy, soc: SocSpec, dla_version: DlaVersion) -> Replanner {
        Replanner {
            policy,
            soc,
            dla_version,
            checks: 0,
            cooldown: 0,
            settled_key: None,
            settled_backlog: 0,
        }
    }

    pub fn policy(&self) -> &ReplanPolicy {
        &self.policy
    }

    /// Consult at a checkpoint. `backlog` is admitted-but-uncompleted
    /// frames. Returns a proposal when the serve loop should switch.
    pub fn consider(
        &mut self,
        spec: &PipelineSpec,
        window: &WindowStats,
        backlog: usize,
    ) -> Result<Option<Proposal>> {
        if !self.policy.enabled {
            return Ok(None);
        }
        self.checks += 1;

        if let Some(every) = self.policy.force_every_checks {
            if every > 0 && self.checks % every == 0 {
                // Forced handoff: re-plan if possible, otherwise switch to
                // an identical spec — the drain-and-switch path runs
                // either way (what the property tests exercise).
                let next = self.plan_for(spec, backlog)?.unwrap_or_else(|| spec.clone());
                return Ok(Some(Proposal {
                    spec: next,
                    predicted_fps_before: 0.0,
                    predicted_fps_after: 0.0,
                    reason: "forced".into(),
                }));
            }
        }

        if self.cooldown > 0 {
            self.cooldown -= 1;
            return Ok(None);
        }

        // Trigger: engines idling, or offered load outrunning service.
        let idle = window.idle_frac();
        let backlogged = backlog > self.policy.check_every_frames / 2;
        let key = spec_key(spec);
        let settled = self.settled_key.as_deref() == Some(key.as_str());
        // A settled spec re-opens only when the backlog has materially
        // worsened since the search last came up empty — a steady
        // overload plateau must not pay the search every checkpoint.
        let distress = backlogged
            && (!settled
                || backlog > (self.settled_backlog.saturating_mul(2))
                    .max(self.policy.check_every_frames));
        let reason = if distress {
            format!("backlog {backlog} frames")
        } else if idle >= self.policy.idle_frac_threshold && !settled {
            format!("idle {:.2} >= {:.2}", idle, self.policy.idle_frac_threshold)
        } else {
            return Ok(None);
        };

        let Some(planned) = self.plan_for(spec, backlog)? else {
            // Nothing plannable in this spec: never search it again.
            self.settled_key = Some(key);
            self.settled_backlog = backlog;
            return Ok(None);
        };
        // Price both sides with the same virtual-time scorer.
        if spec_key(&planned) != key {
            let window_frames = self.policy.check_every_frames.clamp(16, 128);
            let current = placement::evaluate(spec, &self.soc, window_frames)?;
            let next = placement::evaluate(&planned, &self.soc, window_frames)?;
            if next.predicted_fps > current.predicted_fps * (1.0 + self.policy.min_gain) {
                self.cooldown = self.policy.cooldown_checks;
                self.settled_key = None;
                return Ok(Some(Proposal {
                    spec: planned,
                    predicted_fps_before: current.predicted_fps,
                    predicted_fps_after: next.predicted_fps,
                    reason,
                }));
            }
        }
        // The search found nothing better: the spec is settled until it
        // changes or the backlog materially worsens.
        self.settled_key = Some(key);
        self.settled_backlog = backlog;
        Ok(None)
    }

    /// Run the placement search for the observed workload shape; `None`
    /// when the spec has nothing plannable (no GAN instances).
    fn plan_for(&self, spec: &PipelineSpec, backlog: usize) -> Result<Option<PipelineSpec>> {
        let Some(mut req) =
            PlacementRequest::for_spec(spec, self.soc.clone(), self.dla_version)
        else {
            return Ok(None);
        };
        req.frames = self.policy.check_every_frames.clamp(16, 128);
        if backlog > self.policy.check_every_frames {
            // Deep backlog: open the batching axis — amortized dispatch is
            // how a saturated engine claws throughput back.
            if !req.max_batches.contains(&8) {
                req.max_batches.push(8);
            }
        }
        Ok(Some(placement::plan(&req)?.spec))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::hw::{orin, EngineKind};
    use crate::pipeline::router::RoutePolicy;
    use crate::pipeline::spec::InstanceSpec;

    fn window(idle_busy: &[(&str, f64)]) -> WindowStats {
        WindowStats {
            t0: 0.0,
            t1: 1.0,
            completed: 100,
            fps: 100.0,
            latency_ms_p50: 5.0,
            latency_ms_p95: 9.0,
            latency_ms_p99: 10.0,
            offered: 100,
            shed: 0,
            dropped: 0,
            arrival_fps: 100.0,
            engine_busy: idle_busy
                .iter()
                .map(|(l, b)| (l.to_string(), *b))
                .collect(),
        }
    }

    fn same_dla0_pair() -> PipelineSpec {
        PipelineSpec {
            instances: vec![
                InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, 0),
                InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, 0),
            ],
            route: RoutePolicy::RoundRobin,
            ..PipelineSpec::default()
        }
    }

    #[test]
    fn spec_key_ignores_stream_shape_but_sees_placement() {
        let a = same_dla0_pair();
        let mut b = same_dla0_pair();
        b.frames = 9999;
        b.seed = 1;
        assert_eq!(spec_key(&a), spec_key(&b));
        let mut c = same_dla0_pair();
        c.instances[1].engine_index = 1;
        assert_ne!(spec_key(&a), spec_key(&c));
        let mut d = same_dla0_pair();
        d.instances[0].batch.max_batch = 4;
        assert_ne!(spec_key(&a), spec_key(&d));
    }

    #[test]
    fn idle_engines_trigger_a_better_placement() {
        // Both GANs pinned to DLA0: GPU and DLA1 sit idle. The planner
        // must find a split placement with a large predicted gain.
        let mut rp = Replanner::new(ReplanPolicy::default(), orin(), DlaVersion::V2);
        let spec = same_dla0_pair();
        let w = window(&[("GPU", 0.0), ("DLA0", 0.95), ("DLA1", 0.0)]);
        let prop = rp
            .consider(&spec, &w, 0)
            .unwrap()
            .expect("idle units with a plannable gain must propose a switch");
        assert!(prop.predicted_fps_after > prop.predicted_fps_before * 1.5);
        assert_ne!(spec_key(&prop.spec), spec_key(&spec));
        assert!(prop.reason.contains("idle"));
        // cooldown: the very next checkpoint stays quiet
        assert!(rp.consider(&spec, &w, 0).unwrap().is_none());
    }

    #[test]
    fn planner_optimal_spec_settles_under_structural_idle() {
        // A GAN-only spec always leaves the GPU cold, so idle_frac stays
        // above the threshold forever. Once a search confirms there is
        // nothing better, idle-only checkpoints must stop proposing (and
        // stop burning placement searches) until a backlog reappears.
        let req = PlacementRequest::for_spec(
            &same_dla0_pair(),
            orin(),
            DlaVersion::V2,
        )
        .unwrap();
        let best = placement::plan(&req).unwrap().spec;
        let mut rp = Replanner::new(
            ReplanPolicy {
                cooldown_checks: 0,
                ..ReplanPolicy::default()
            },
            orin(),
            DlaVersion::V2,
        );
        let idle = window(&[("GPU", 0.0), ("DLA0", 0.9), ("DLA1", 0.9)]);
        for _ in 0..4 {
            assert!(
                rp.consider(&best, &idle, 0).unwrap().is_none(),
                "the already-optimal spec must not thrash"
            );
        }
    }

    #[test]
    fn busy_balanced_serving_does_not_thrash() {
        let mut rp = Replanner::new(ReplanPolicy::default(), orin(), DlaVersion::V2);
        let spec = same_dla0_pair();
        let w = window(&[("GPU", 0.9), ("DLA0", 0.9), ("DLA1", 0.9)]);
        assert!(rp.consider(&spec, &w, 0).unwrap().is_none(), "no idle, no backlog");
    }

    #[test]
    fn disabled_and_unplannable_specs_stay_put() {
        let mut rp = Replanner::new(ReplanPolicy::disabled(), orin(), DlaVersion::V2);
        let w = window(&[("GPU", 0.0), ("DLA0", 0.0), ("DLA1", 0.0)]);
        assert!(rp.consider(&same_dla0_pair(), &w, 10_000).unwrap().is_none());
        // detector-only spec: nothing for the planner to place
        let mut rp = Replanner::new(ReplanPolicy::default(), orin(), DlaVersion::V2);
        let yolo_only = PipelineSpec {
            instances: vec![InstanceSpec::new("y", "yolo_lite")],
            ..PipelineSpec::default()
        };
        assert!(rp.consider(&yolo_only, &w, 10_000).unwrap().is_none());
    }

    #[test]
    fn forced_switch_fires_even_without_pressure() {
        let policy = ReplanPolicy {
            force_every_checks: Some(2),
            ..ReplanPolicy::default()
        };
        let mut rp = Replanner::new(policy, orin(), DlaVersion::V2);
        let spec = same_dla0_pair();
        let quiet = window(&[("GPU", 1.0), ("DLA0", 1.0), ("DLA1", 1.0)]);
        assert!(rp.consider(&spec, &quiet, 0).unwrap().is_none());
        let prop = rp.consider(&spec, &quiet, 0).unwrap().expect("every 2nd check forces");
        assert_eq!(prop.reason, "forced");
    }
}
