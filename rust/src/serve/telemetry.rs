//! Rolling serve telemetry.
//!
//! A [`Telemetry`] hub implements the driver's
//! [`CompletionSink`](crate::pipeline::CompletionSink): every worker
//! reports each finished frame, and the serve loop derives *windowed*
//! statistics from the retained event tail — FPS, latency percentiles
//! (p50/p95/p99), and per-engine busy fractions cut from the
//! [`EngineArbiter`](crate::pipeline::engines::EngineArbiter)'s live
//! timeline. Windows are what the online re-planner watches: full-run
//! aggregates would smear a load shift into invisibility.

// The completion sink runs once per served frame on worker threads: it
// must degrade on poisoning, never panic (see util::lock).
#![deny(clippy::unwrap_used)]

use crate::config::json::{arr, num, obj, s, Json};
use crate::hw::EngineKind;
use crate::pipeline::driver::CompletionSink;
use crate::sim::timeline::Timeline;
use crate::util::lock::relock;
use crate::util::stats::Summary;
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One completed frame, on the telemetry clock.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    /// Instance index within the then-active spec.
    pub instance: usize,
    /// Source client stream.
    pub stream: usize,
    /// Frame id within its stream.
    pub frame_id: u64,
    /// Completion time, seconds since telemetry epoch (wall clock).
    pub t: f64,
    /// Admission-to-completion latency, seconds.
    pub latency_s: f64,
}

#[derive(Debug, Default)]
struct Inner {
    /// Retained completion tail (ring, capped).
    events: VecDeque<Completion>,
    /// Monotonic completion count (never truncated).
    completed: usize,
    /// Full-run latency accumulator (exact percentiles at report time).
    latency: Summary,
}

/// Thread-safe completion hub shared by every worker across every
/// drain-and-switch phase — counters and latency aggregates survive spec
/// swaps, which is what makes cross-phase conservation checkable.
#[derive(Debug)]
pub struct Telemetry {
    epoch: Instant,
    cap: usize,
    inner: Mutex<Inner>,
}

impl Telemetry {
    /// `cap` bounds the retained event tail (windowed queries and the
    /// optional completion record); counters and the latency summary are
    /// unaffected by the cap.
    pub fn new(cap: usize) -> Telemetry {
        Telemetry {
            epoch: Instant::now(),
            cap: cap.max(1),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Seconds since telemetry epoch (the serve clock).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    pub fn total_completed(&self) -> usize {
        relock(&self.inner).completed
    }

    /// Full-run latency percentile in milliseconds.
    pub fn latency_ms_percentile(&self, q: f64) -> f64 {
        relock(&self.inner).latency.percentile(q) * 1e3
    }

    /// Copy of the retained completion tail (oldest first). One full-ring
    /// clone — fine for a final report; checkpoints on long runs should
    /// pull increments with [`Telemetry::completions_since`] instead.
    pub fn completions(&self) -> Vec<Completion> {
        relock(&self.inner).events.iter().copied().collect()
    }

    /// Append only the completions the caller has not seen yet to `out`
    /// and return the new cursor. `cursor` is the monotonic completed
    /// count a previous call returned (`0` to start). Cost is O(new
    /// events), not O(ring): the serve loop pulls at every checkpoint, so
    /// an open-ended run never re-clones its whole tail. Events that aged
    /// out of the capped ring between pulls are skipped — the returned
    /// cursor still advances past them, so nothing is double-counted.
    pub fn completions_since(
        &self,
        cursor: usize,
        out: &mut std::collections::VecDeque<Completion>,
    ) -> usize {
        let inner = relock(&self.inner);
        let unseen = inner.completed.saturating_sub(cursor);
        let start = inner.events.len().saturating_sub(unseen);
        out.extend(inner.events.range(start..).copied());
        inner.completed
    }

    /// Completion statistics over the wall-time window `(t0, t1]`.
    pub fn window(&self, t0: f64, t1: f64) -> (usize, Summary) {
        let inner = relock(&self.inner);
        let mut lat = Summary::new();
        let mut completed = 0;
        // events are time-ordered; scan the tail backwards
        for ev in inner.events.iter().rev() {
            if ev.t <= t0 {
                break;
            }
            if ev.t <= t1 {
                completed += 1;
                lat.add(ev.latency_s);
            }
        }
        (completed, lat)
    }
}

impl CompletionSink for Telemetry {
    fn completed(&self, instance: usize, stream: usize, frame_id: u64, latency_s: f64) {
        let mut inner = relock(&self.inner);
        // Stamp *inside* the lock: stamping before it would let a
        // preempted worker append a stale timestamp after a newer one,
        // breaking the time-ordering `window()`'s reverse scan relies on.
        let t = self.now();
        inner.completed += 1;
        inner.latency.add(latency_s);
        if inner.events.len() == self.cap {
            inner.events.pop_front();
        }
        inner.events.push_back(Completion {
            instance,
            stream,
            frame_id,
            t,
            latency_s,
        });
    }
}

/// One telemetry window snapshot — the serve report's time series and the
/// re-planner's input.
#[derive(Debug, Clone)]
pub struct WindowStats {
    /// Window bounds, seconds on the serve clock.
    pub t0: f64,
    pub t1: f64,
    /// Frames completed in the window (all instances).
    pub completed: usize,
    /// Completions per wall second.
    pub fps: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    /// Frames offered to admission in the window.
    pub offered: usize,
    /// Frames admission-shed in the window (not cumulative — each shed
    /// is attributed to exactly one window, so fleet rollups can place
    /// loss in time).
    pub shed: usize,
    /// Droppable fanout copies discarded on overload in the window
    /// (the pipeline's `dropped` ledger, windowed the same way).
    pub dropped: usize,
    /// Offered arrival rate in *model* fps (the load profile's clock).
    pub arrival_fps: f64,
    /// Busy fraction per physical unit over the window, **all SoC units**
    /// — units the current spec leaves unused report `0.0`, which is
    /// precisely the idle capacity the re-planner hunts for.
    pub engine_busy: Vec<(String, f64)>,
}

impl WindowStats {
    /// Mean idle fraction across the SoC's units (1 − mean busy): the
    /// re-planner's primary trigger signal.
    pub fn idle_frac(&self) -> f64 {
        if self.engine_busy.is_empty() {
            return 0.0;
        }
        let busy: f64 = self.engine_busy.iter().map(|(_, b)| b).sum();
        (1.0 - busy / self.engine_busy.len() as f64).max(0.0)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("t0", num(self.t0)),
            ("t1", num(self.t1)),
            ("completed", num(self.completed as f64)),
            ("fps", num(self.fps)),
            ("latency_ms_p50", num(self.latency_ms_p50)),
            ("latency_ms_p95", num(self.latency_ms_p95)),
            ("latency_ms_p99", num(self.latency_ms_p99)),
            ("offered", num(self.offered as f64)),
            ("shed", num(self.shed as f64)),
            ("dropped", num(self.dropped as f64)),
            ("arrival_fps", num(self.arrival_fps)),
            ("idle_frac", num(self.idle_frac())),
            (
                "engines",
                arr(self
                    .engine_busy
                    .iter()
                    .map(|(label, busy)| {
                        obj(vec![("unit", s(label)), ("busy_frac", num(*busy))])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Completion statistics over `(t0, t1]` from a caller-held, time-ordered
/// completion tail (see [`Telemetry::completions_since`]) — the
/// checkpoint-path equivalent of [`Telemetry::window`] with no lock
/// acquisition and no shared-ring scan.
pub fn window_from_tail(
    tail: &VecDeque<Completion>,
    t0: f64,
    t1: f64,
) -> (usize, Summary) {
    let mut lat = Summary::new();
    let mut completed = 0;
    for ev in tail.iter().rev() {
        if ev.t <= t0 {
            break;
        }
        if ev.t <= t1 {
            completed += 1;
            lat.add(ev.latency_s);
        }
    }
    (completed, lat)
}

/// The SoC's schedulable units (GPU + both DLA cores) — the full set a
/// windowed utilization must cover so unused engines show up as idle.
pub fn soc_units() -> Vec<(EngineKind, usize)> {
    let mut units = Vec::new();
    for kind in [EngineKind::Gpu, EngineKind::Dla] {
        for u in 0..kind.units() {
            units.push((kind, u));
        }
    }
    units
}

/// Per-unit busy fraction over the serve-clock window `(t0, t1)`, from an
/// arbiter timeline whose spans are offset by `offset` seconds relative
/// to the serve clock. Transitions count as busy (the unit is occupied).
pub fn engine_busy_in_window(
    tl: &Timeline,
    offset: f64,
    t0: f64,
    t1: f64,
) -> Vec<(String, f64)> {
    let width = (t1 - t0).max(f64::MIN_POSITIVE);
    soc_units()
        .into_iter()
        .map(|(kind, unit)| {
            let busy: f64 = tl
                .spans
                .iter()
                .filter(|sp| sp.engine == kind && sp.unit == unit)
                .map(|sp| {
                    let a = (sp.t0 + offset).max(t0);
                    let b = (sp.t1 + offset).min(t1);
                    (b - a).max(0.0)
                })
                .sum();
            (kind.unit_label(unit), (busy / width).min(1.0))
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sim::timeline::Span;

    fn span(kind: EngineKind, unit: usize, t0: f64, t1: f64) -> Span {
        Span {
            engine: kind,
            unit,
            instance: 0,
            frame: 0,
            t0,
            t1,
            is_transition: false,
        }
    }

    #[test]
    fn completions_feed_windows_and_totals() {
        let t = Telemetry::new(1024);
        for i in 0..10u64 {
            t.completed(0, 0, i, 0.004);
        }
        assert_eq!(t.total_completed(), 10);
        let (n, lat) = t.window(0.0, t.now() + 1.0);
        assert_eq!(n, 10);
        assert!((lat.p50() - 0.004).abs() < 1e-9);
        assert!(t.latency_ms_percentile(99.0) > 0.0);
        // a window strictly in the future is empty
        let (n, _) = t.window(t.now() + 10.0, t.now() + 20.0);
        assert_eq!(n, 0);
    }

    #[test]
    fn event_tail_is_capped_but_counters_are_not() {
        let t = Telemetry::new(4);
        for i in 0..10u64 {
            t.completed(0, 0, i, 0.001);
        }
        assert_eq!(t.completions().len(), 4);
        assert_eq!(t.completions()[0].frame_id, 6);
        assert_eq!(t.total_completed(), 10);
    }

    #[test]
    fn incremental_pulls_see_each_event_exactly_once() {
        let t = Telemetry::new(1024);
        let mut tail = std::collections::VecDeque::new();
        let mut cursor = t.completions_since(0, &mut tail);
        assert_eq!((cursor, tail.len()), (0, 0));
        for i in 0..6u64 {
            t.completed(0, 0, i, 0.001);
        }
        cursor = t.completions_since(cursor, &mut tail);
        assert_eq!((cursor, tail.len()), (6, 6));
        for i in 6..10u64 {
            t.completed(0, 0, i, 0.001);
        }
        cursor = t.completions_since(cursor, &mut tail);
        assert_eq!((cursor, tail.len()), (10, 10));
        // exactly once, in order
        for (i, ev) in tail.iter().enumerate() {
            assert_eq!(ev.frame_id, i as u64);
        }
        // idempotent when nothing new happened
        assert_eq!(t.completions_since(cursor, &mut tail), 10);
        assert_eq!(tail.len(), 10);
    }

    #[test]
    fn incremental_pull_skips_aged_out_events_without_recount() {
        let t = Telemetry::new(4);
        for i in 0..10u64 {
            t.completed(0, 0, i, 0.001);
        }
        // 6 of the 10 already aged out of the capped ring before the
        // first pull: the cursor jumps past them
        let mut tail = std::collections::VecDeque::new();
        let cursor = t.completions_since(0, &mut tail);
        assert_eq!(cursor, 10);
        assert_eq!(tail.len(), 4);
        assert_eq!(tail[0].frame_id, 6);
    }

    #[test]
    fn unused_units_report_zero_busy() {
        let mut tl = Timeline::default();
        tl.push(span(EngineKind::Dla, 0, 0.0, 1.0));
        let busy = engine_busy_in_window(&tl, 0.0, 0.0, 1.0);
        assert_eq!(busy.len(), 3, "GPU + both DLA cores");
        let get = |label: &str| {
            busy.iter()
                .find(|(l, _)| l == label)
                .map(|(_, b)| *b)
                .unwrap()
        };
        assert!((get("DLA0") - 1.0).abs() < 1e-9);
        assert_eq!(get("DLA1"), 0.0);
        assert_eq!(get("GPU"), 0.0);
        let ws = WindowStats {
            t0: 0.0,
            t1: 1.0,
            completed: 1,
            fps: 1.0,
            latency_ms_p50: 1.0,
            latency_ms_p95: 1.0,
            latency_ms_p99: 1.0,
            offered: 1,
            shed: 0,
            dropped: 0,
            arrival_fps: 1.0,
            engine_busy: busy,
        };
        // 2 of 3 units idle -> idle fraction 2/3
        assert!((ws.idle_frac() - 2.0 / 3.0).abs() < 1e-9);
        crate::config::json::Json::parse(&ws.to_json().to_compact()).unwrap();
    }

    #[test]
    fn window_clips_spans_and_applies_offset() {
        let mut tl = Timeline::default();
        // span [0, 2] on the core clock; phase offset +1 -> [1, 3] serve
        tl.push(span(EngineKind::Gpu, 0, 0.0, 2.0));
        let busy = engine_busy_in_window(&tl, 1.0, 2.0, 4.0);
        let gpu = busy.iter().find(|(l, _)| l == "GPU").unwrap().1;
        // overlap of [1,3] with (2,4) is 1 of 2 seconds
        assert!((gpu - 0.5).abs() < 1e-9);
    }
}
