//! Arithmetic and memory-traffic cost of each layer.

use crate::graph::layer::LayerKind;
use crate::graph::shape::Shape;
use crate::graph::{Graph, NodeId};

/// Static cost of one layer instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCost {
    /// Multiply-accumulate-style floating point operations (1 MAC = 2 FLOP).
    pub flops: f64,
    /// Bytes moved to/from DRAM: inputs + outputs + parameters.
    pub bytes: f64,
    /// True when the op is MAC-array work (conv/deconv/dense), false for
    /// element-wise / data-movement ops that bypass the MXU/MAC core.
    pub is_mac: bool,
    /// Transposed convolution (engines differ in how efficiently they map
    /// it — see [`crate::hw::EngineSpec::deconv_boost`]).
    pub is_deconv: bool,
}

impl LayerCost {
    pub const ZERO: LayerCost = LayerCost {
        flops: 0.0,
        bytes: 0.0,
        is_mac: false,
        is_deconv: false,
    };
}

/// Aggregate cost of a node set: flops/bytes summed, MAC-ness ORed — the
/// PCCS contention inputs. Single definition shared by the discrete-event
/// sim's per-segment aggregation ([`crate::sim::soc_sim`]) and the
/// serving arbiter's dispatch pricing
/// ([`crate::pipeline::backend::SimBackend`]), so the two execution paths
/// feed the contention model identically.
pub fn aggregate_cost(graph: &Graph, ids: &[NodeId]) -> LayerCost {
    let mut agg = LayerCost::ZERO;
    for &id in ids {
        let c = node_cost(graph, id);
        agg.flops += c.flops;
        agg.bytes += c.bytes;
        agg.is_mac |= c.is_mac;
    }
    agg
}

/// Bytes of model parameters a layer fetches per dispatch (FP16 weights).
/// Single source of truth for the weight-precision factor — `layer_cost`
/// folds this into `bytes`, and the batched roofline
/// ([`crate::cost::latency::batched_layer_latency`]) splits it back out
/// to amortize weight traffic across a batch.
pub fn layer_param_bytes(kind: &LayerKind, inputs: &[Shape]) -> f64 {
    kind.param_count(inputs) as f64 * 2.0
}

/// Compute cost of a layer from its attributes and I/O shapes.
pub fn layer_cost(kind: &LayerKind, inputs: &[Shape], output: Shape) -> LayerCost {
    use LayerKind::*;
    let in_bytes: f64 = inputs.iter().map(|s| s.bytes() as f64).sum();
    let out_bytes = output.bytes() as f64;
    let param_bytes = layer_param_bytes(kind, inputs);
    let io = in_bytes + out_bytes + param_bytes;

    match kind {
        Input { .. } | Output | Identity | Dropout { .. } => LayerCost::ZERO,
        Conv2d {
            kernel, groups, ..
        } => {
            let in_c = inputs.first().map(|s| s.c).unwrap_or(0) as f64;
            let macs =
                output.numel() as f64 * (in_c / *groups as f64) * (*kernel * *kernel) as f64;
            LayerCost {
                flops: 2.0 * macs,
                bytes: io,
                is_mac: true,
                is_deconv: false,
            }
        }
        ConvTranspose2d { kernel, .. } => {
            // Deconv as zero-insertion conv: each *input* element
            // contributes k*k*out_c MACs.
            let in_numel = inputs.first().map(|s| s.numel()).unwrap_or(0) as f64;
            let macs = in_numel * (*kernel * *kernel) as f64 * output.c as f64;
            LayerCost {
                flops: 2.0 * macs,
                bytes: io,
                is_mac: true,
                is_deconv: true,
            }
        }
        Dense { out_features } => {
            let in_f = inputs.first().map(|s| s.numel()).unwrap_or(0) as f64;
            LayerCost {
                flops: 2.0 * in_f * *out_features as f64,
                bytes: io,
                is_mac: true,
                is_deconv: false,
            }
        }
        BatchNorm | InstanceNorm => LayerCost {
            flops: 2.0 * output.numel() as f64,
            bytes: io,
            is_mac: false,
            is_deconv: false,
        },
        ReLU | LeakyReLU { .. } | Sigmoid | Tanh | SiLU | Softmax => LayerCost {
            flops: output.numel() as f64 * 4.0,
            bytes: io,
            is_mac: false,
            is_deconv: false,
        },
        MaxPool { kernel, .. } | AvgPool { kernel, .. } => LayerCost {
            flops: output.numel() as f64 * (*kernel * *kernel) as f64,
            bytes: io,
            is_mac: false,
            is_deconv: false,
        },
        GlobalAvgPool => LayerCost {
            flops: inputs.first().map(|s| s.numel()).unwrap_or(0) as f64,
            bytes: io,
            is_mac: false,
            is_deconv: false,
        },
        Concat | Add | Crop { .. } | ZeroPad { .. } | Upsample { .. } | SliceChannels { .. }
        | Cast { .. } => LayerCost {
            flops: output.numel() as f64,
            bytes: io,
            is_mac: false,
            is_deconv: false,
        },
    }
}

/// Cost of one node of a graph.
pub fn node_cost(graph: &Graph, id: NodeId) -> LayerCost {
    let node = graph.node(id);
    layer_cost(&node.kind, &graph.input_shapes(id), node.shape)
}

/// Total FLOPs of a graph (one inference).
pub fn graph_flops(graph: &Graph) -> f64 {
    (0..graph.len()).map(|id| node_cost(graph, id).flops).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::graph::shape::DType;
    use crate::models::pix2pix::{generator, Pix2PixConfig};

    fn f16(c: usize, hw: usize) -> Shape {
        Shape::new(c, hw, hw, DType::F16)
    }

    #[test]
    fn conv_flops_formula() {
        let conv = LayerKind::conv(64, 3, 1, 1);
        let out = conv.infer_shape(&[f16(32, 16)]).unwrap();
        let c = layer_cost(&conv, &[f16(32, 16)], out);
        // 2 * out_numel * in_c * k^2 = 2 * 64*16*16 * 32 * 9
        assert_eq!(c.flops, 2.0 * (64.0 * 256.0) * 32.0 * 9.0);
        assert!(c.is_mac);
    }

    #[test]
    fn deconv_flops_symmetry() {
        // A stride-2 deconv has the same MAC count as the stride-2 conv of
        // the reverse direction.
        let deconv = LayerKind::deconv(32, 4, 2, 1);
        let out = deconv.infer_shape(&[f16(64, 8)]).unwrap();
        let c = layer_cost(&deconv, &[f16(64, 8)], out);
        assert_eq!(c.flops, 2.0 * (64.0 * 64.0) * 16.0 * 32.0);
    }

    #[test]
    fn elementwise_is_not_mac() {
        let relu = LayerKind::ReLU;
        let c = layer_cost(&relu, &[f16(8, 8)], f16(8, 8));
        assert!(!c.is_mac);
        assert!(c.flops > 0.0);
    }

    #[test]
    fn markers_are_free() {
        let c = layer_cost(
            &LayerKind::Input { shape: f16(3, 256) },
            &[],
            f16(3, 256),
        );
        assert_eq!(c, LayerCost::ZERO);
    }

    #[test]
    fn pix2pix_total_flops_plausible() {
        // Full 256x256 pix2pix generator ≈ 18 GFLOP (2x the ~9 GMAC
        // figure commonly reported).
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let f = graph_flops(&g);
        assert!(
            (10e9..40e9).contains(&f),
            "pix2pix flops {f:.3e} outside plausible band"
        );
    }

    #[test]
    fn conv_variant_costs_more_than_crop() {
        let crop = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        let conv = generator(&Pix2PixConfig::paper(), GanVariant::Convolution).unwrap();
        assert!(graph_flops(&conv) > graph_flops(&crop));
    }
}
