//! Roofline latency model.
//!
//! Per-layer latency on an engine is the roofline maximum of compute time
//! and memory time plus a fixed launch overhead:
//!
//! ```text
//! t = max(flops / effective_flops, bytes / mem_bw) + launch
//! ```
//!
//! MAC ops (conv/deconv/dense) use the MAC-array rate; element-wise ops use
//! the engine's (much lower) element-wise rate — this is what makes the
//! modified Pix2Pix variants *slower standalone* (their extra crop/conv
//! layers add launches and element work) even though they win concurrent
//! execution, reproducing the paper's Fig 9 vs Table IV crossover.

use super::flops::{node_cost, LayerCost};
use crate::graph::{Graph, NodeId};
use crate::hw::{EngineKind, EngineSpec, SocSpec};

/// Latency model over a SoC.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    pub soc: SocSpec,
}

/// Latency of one layer cost on one engine, seconds.
pub fn layer_latency(cost: &LayerCost, engine: &EngineSpec) -> f64 {
    batched_layer_latency(cost, 0.0, engine, 1)
}

/// Roofline latency of one layer executed as a **batched dispatch** of
/// `n` frames, seconds: compute and activation traffic scale with `n`,
/// while the weight fetch (`param_bytes` of `cost.bytes`) and the kernel
/// launch are paid once per dispatch. `n == 1` is exactly
/// [`layer_latency`] (the activation/weight split cancels out there), so
/// the single-frame calibration and the batched pricing cannot drift.
pub fn batched_layer_latency(
    cost: &LayerCost,
    param_bytes: f64,
    engine: &EngineSpec,
    n: usize,
) -> f64 {
    if cost.flops == 0.0 && cost.bytes == 0.0 {
        return 0.0; // structural markers
    }
    let n = n.max(1) as f64;
    let compute = if cost.is_mac {
        let eff = engine.effective_flops()
            * if cost.is_deconv { engine.deconv_boost } else { 1.0 };
        cost.flops / eff
    } else {
        // element ops: flops here counts elements processed
        cost.flops / engine.elementwise_rate
    };
    let act_bytes = (cost.bytes - param_bytes).max(0.0);
    let memory = (n * act_bytes + param_bytes) / engine.mem_bw;
    (n * compute).max(memory) + engine.launch_overhead
}

impl LatencyModel {
    pub fn new(soc: SocSpec) -> Self {
        LatencyModel { soc }
    }

    /// Latency of node `id` of `graph` on `engine`.
    pub fn node_latency(&self, graph: &Graph, id: NodeId, engine: EngineKind) -> f64 {
        layer_latency(&node_cost(graph, id), self.soc.engine(engine))
    }

    /// Sum of node latencies for a contiguous node set on one engine.
    pub fn nodes_latency(&self, graph: &Graph, nodes: &[NodeId], engine: EngineKind) -> f64 {
        nodes
            .iter()
            .map(|&id| self.node_latency(graph, id, engine))
            .sum()
    }

    /// Whole-graph latency on a single engine (no transitions).
    pub fn graph_latency(&self, graph: &Graph, engine: EngineKind) -> f64 {
        self.nodes_latency(graph, &graph.compute_layers(), engine)
    }

    /// Transition (reformat) latency for handing `bytes` between engines.
    pub fn transition_latency(&self, bytes: usize) -> f64 {
        self.soc.transition.latency(bytes)
    }

    /// Latency of an [`crate::dla::EnginePlan`]-style segmented execution:
    /// sum of segment latencies plus a transition for every boundary, using
    /// the producing node's output bytes as transfer size.
    pub fn plan_latency(&self, graph: &Graph, plan: &crate::dla::EnginePlan) -> f64 {
        let mut total = 0.0;
        for (i, seg) in plan.segments.iter().enumerate() {
            total += self.nodes_latency(graph, &seg.nodes, seg.engine);
            if i + 1 < plan.segments.len() {
                let last = *seg.nodes.last().expect("non-empty segment");
                total += self.transition_latency(graph.node(last).shape.bytes());
            }
        }
        total
    }
}

/// Convenience: single-engine graph latency.
pub fn graph_latency(graph: &Graph, soc: &SocSpec, engine: EngineKind) -> f64 {
    LatencyModel::new(soc.clone()).graph_latency(graph, engine)
}

/// Convenience: latency of a node slice on an engine.
pub fn segment_latency(graph: &Graph, nodes: &[NodeId], soc: &SocSpec, engine: EngineKind) -> f64 {
    LatencyModel::new(soc.clone()).nodes_latency(graph, nodes, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::dla::planner::plan_with_island;
    use crate::dla::{plan, DlaVersion};
    use crate::hw::orin;
    use crate::models::pix2pix::{generator, Pix2PixConfig};

    fn model(v: GanVariant) -> crate::graph::Graph {
        generator(&Pix2PixConfig::paper(), v).unwrap()
    }

    #[test]
    fn gpu_calibration_near_paper_fps() {
        // Calibration target: original Pix2Pix on the Orin GPU ≈ 172.59 FPS
        // (Table IV). Accept ±10%.
        let m = LatencyModel::new(orin());
        let t = m.graph_latency(&model(GanVariant::Original), EngineKind::Gpu);
        let fps = 1.0 / t;
        assert!(
            (155.0..190.0).contains(&fps),
            "orin gpu pix2pix fps = {fps:.1}"
        );
    }

    #[test]
    fn dla_slower_than_gpu_for_same_graph() {
        let m = LatencyModel::new(orin());
        let g = model(GanVariant::Cropping);
        let t_gpu = m.graph_latency(&g, EngineKind::Gpu);
        let t_dla = m.graph_latency(&g, EngineKind::Dla);
        assert!(t_dla > t_gpu);
        assert!(t_dla < 3.0 * t_gpu, "DLA within 3x of GPU");
    }

    #[test]
    fn modified_variants_slower_standalone_fig9() {
        // Fig 9: original (with fallback) beats the pure-DLA modified
        // models standalone.
        let m = LatencyModel::new(orin());
        let orig_plan =
            plan_with_island(&model(GanVariant::Original), DlaVersion::V2, 16, 3).unwrap();
        let t_orig = m.plan_latency(&model(GanVariant::Original), &orig_plan);

        for v in [GanVariant::Cropping, GanVariant::Convolution] {
            let g = model(v);
            let p = plan_with_island(&g, DlaVersion::V2, 16, 3).unwrap();
            assert!(p.fully_dla_resident());
            let t = m.plan_latency(&g, &p);
            assert!(
                t > t_orig,
                "{v:?} standalone ({:.2} ms) must be slower than original ({:.2} ms)",
                t * 1e3,
                t_orig * 1e3
            );
        }
    }

    #[test]
    fn batched_layer_latency_amortizes_weights_and_launch() {
        let soc = orin();
        let engine = soc.engine(EngineKind::Gpu);
        let cost = LayerCost {
            flops: 1e9,
            bytes: 9e6,
            is_mac: true,
            is_deconv: false,
        };
        // n = 1 is exactly the single-frame roofline, any weight split
        let single = layer_latency(&cost, engine);
        assert_eq!(batched_layer_latency(&cost, 0.0, engine, 1), single);
        assert_eq!(batched_layer_latency(&cost, 8e6, engine, 1), single);
        // a batch of 4 amortizes the launch and the 8 MB of weights
        let b4 = batched_layer_latency(&cost, 8e6, engine, 4);
        assert!(b4 < 4.0 * single);
        assert!(b4 >= single);
    }

    #[test]
    fn transitions_add_cost() {
        let m = LatencyModel::new(orin());
        let g = model(GanVariant::Original);
        let p = plan(&g, DlaVersion::V2, 16).unwrap();
        let seg_only: f64 = p
            .segments
            .iter()
            .map(|s| m.nodes_latency(&g, &s.nodes, s.engine))
            .sum();
        assert!(m.plan_latency(&g, &p) > seg_only);
    }

    #[test]
    fn xavier_slower_than_orin() {
        let g = model(GanVariant::Original);
        let t_orin = graph_latency(&g, &orin(), EngineKind::Gpu);
        let t_xavier = graph_latency(&g, &crate::hw::xavier(), EngineKind::Gpu);
        assert!(t_xavier > 2.0 * t_orin);
    }
}
