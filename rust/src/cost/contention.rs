//! PCCS-style memory-contention model.
//!
//! HaX-CoNN's processor-centric contention-aware slowdown (PCCS) models the
//! slowdown each engine experiences when another engine is concurrently
//! pulling bandwidth from the shared DRAM. We implement the same idea:
//! an engine's slowdown grows with (a) its own memory-boundedness and
//! (b) the bandwidth demand of the co-runner, saturating when combined
//! demand exceeds the DRAM capability.

use super::flops::LayerCost;
use crate::hw::{EngineSpec, SocSpec};

/// Bandwidth demand (bytes/s) of a layer running alone on an engine:
/// bytes moved divided by its isolated latency.
pub fn bandwidth_demand(cost: &LayerCost, engine: &EngineSpec) -> f64 {
    let t = super::latency::layer_latency(cost, engine);
    if t <= 0.0 {
        0.0
    } else {
        cost.bytes / t
    }
}

/// Slowdown factor (≥ 1) for an engine whose co-runner demands
/// `corunner_bw` bytes/s of the shared DRAM.
///
/// `self_intensity` is the fraction of the engine's time that is
/// memory-bound (0 = pure compute, 1 = pure streaming): compute-bound
/// phases hide contention, memory-bound phases feel it fully.
pub fn slowdown(soc: &SocSpec, self_intensity: f64, corunner_bw: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&self_intensity));
    slowdown_parts(soc.contention_gamma, soc.dram_bw, self_intensity, corunner_bw)
}

/// The PCCS formula from raw parts — the single definition shared by the
/// SoC-level [`slowdown`] (discrete-event sim) and the serving arbiter's
/// [`crate::pipeline::engines::DispatchProfile`], so the two execution
/// paths cannot drift apart.
pub fn slowdown_parts(gamma: f64, dram_bw: f64, self_intensity: f64, corunner_bw: f64) -> f64 {
    if dram_bw <= 0.0 {
        return 1.0;
    }
    let pressure = (corunner_bw / dram_bw).clamp(0.0, 1.0);
    1.0 + gamma * self_intensity.clamp(0.0, 1.0) * pressure
}

/// Memory intensity of a layer on an engine: ratio of memory time to
/// roofline time.
pub fn memory_intensity(cost: &LayerCost, engine: &EngineSpec) -> f64 {
    if cost.flops == 0.0 && cost.bytes == 0.0 {
        return 0.0;
    }
    let compute = if cost.is_mac {
        let eff = engine.effective_flops()
            * if cost.is_deconv { engine.deconv_boost } else { 1.0 };
        cost.flops / eff
    } else {
        cost.flops / engine.elementwise_rate
    };
    let memory = cost.bytes / engine.mem_bw;
    if compute <= 0.0 && memory <= 0.0 {
        0.0
    } else {
        memory / compute.max(memory)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::orin;

    fn mac_cost() -> LayerCost {
        LayerCost {
            flops: 1e9,
            bytes: 1e6,
            is_mac: true,
            is_deconv: false,
        }
    }

    fn streaming_cost() -> LayerCost {
        LayerCost {
            flops: 1e6,
            bytes: 1e8,
            is_mac: false,
            is_deconv: false,
        }
    }

    #[test]
    fn no_corunner_no_slowdown() {
        let soc = orin();
        assert_eq!(slowdown(&soc, 1.0, 0.0), 1.0);
        assert_eq!(slowdown(&soc, 0.0, 1e11), 1.0);
    }

    #[test]
    fn slowdown_monotone_in_pressure() {
        let soc = orin();
        let s1 = slowdown(&soc, 0.8, 20e9);
        let s2 = slowdown(&soc, 0.8, 80e9);
        let s3 = slowdown(&soc, 0.8, 400e9); // saturates at dram_bw
        assert!(s1 < s2);
        assert!(s2 < s3);
        assert!(s3 <= 1.0 + soc.contention_gamma);
    }

    #[test]
    fn compute_bound_layers_feel_less() {
        let soc = orin();
        let mac_int = memory_intensity(&mac_cost(), &soc.gpu);
        let str_int = memory_intensity(&streaming_cost(), &soc.gpu);
        assert!(mac_int < str_int);
        assert!(slowdown(&soc, mac_int, 100e9) < slowdown(&soc, str_int, 100e9));
    }

    #[test]
    fn bandwidth_demand_bounded_by_membw() {
        let soc = orin();
        let d = bandwidth_demand(&streaming_cost(), &soc.gpu);
        assert!(d > 0.0);
        assert!(d <= soc.gpu.mem_bw * 1.01);
    }

    #[test]
    fn intensity_in_unit_range() {
        let soc = orin();
        for c in [mac_cost(), streaming_cost(), LayerCost::ZERO] {
            let i = memory_intensity(&c, &soc.dla);
            assert!((0.0..=1.0).contains(&i), "intensity {i}");
        }
    }
}
