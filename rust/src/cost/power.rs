//! Tegrastats-like power model.
//!
//! The paper's own power measurements were inconclusive (execution order
//! affected readings; values converged with trials — §VI.A). We still model
//! power for completeness: each engine has an idle floor and a dynamic
//! component proportional to utilization, matching the structure of
//! tegrastats' per-rail readouts.

use crate::hw::EngineKind;

/// Power characteristics of one engine, watts.
#[derive(Debug, Clone, Copy)]
pub struct PowerRail {
    pub idle_w: f64,
    pub peak_w: f64,
}

/// Per-engine rails of a Jetson-class SoC.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub gpu: PowerRail,
    pub dla: PowerRail,
    pub cpu: PowerRail,
    pub soc_static_w: f64,
}

impl PowerModel {
    /// Orin-class rails (30 W mode).
    pub fn orin() -> Self {
        PowerModel {
            gpu: PowerRail {
                idle_w: 1.2,
                peak_w: 16.0,
            },
            dla: PowerRail {
                idle_w: 0.3,
                // The DLA's selling point: an order of magnitude less
                // power than the GPU at meaningful throughput.
                peak_w: 3.2,
            },
            cpu: PowerRail {
                idle_w: 0.8,
                peak_w: 9.0,
            },
            soc_static_w: 2.5,
        }
    }

    /// Xavier-class rails (30 W mode): older process node — higher idle
    /// floors and lower peak headroom than Orin at the same budget.
    pub fn xavier() -> Self {
        PowerModel {
            gpu: PowerRail {
                idle_w: 1.6,
                peak_w: 14.0,
            },
            dla: PowerRail {
                idle_w: 0.4,
                peak_w: 2.8,
            },
            cpu: PowerRail {
                idle_w: 1.1,
                peak_w: 8.0,
            },
            soc_static_w: 3.2,
        }
    }

    /// Rails matching a SoC profile by name (`jetson-agx-xavier` → the
    /// Xavier rails, everything else → Orin). Keeps fleet nodes from
    /// hand-pairing a SoC spec with the wrong power table.
    pub fn for_soc(soc: &crate::hw::SocSpec) -> Self {
        if soc.name.contains("xavier") {
            PowerModel::xavier()
        } else {
            PowerModel::orin()
        }
    }

    fn rail(&self, e: EngineKind) -> PowerRail {
        match e {
            EngineKind::Gpu => self.gpu,
            EngineKind::Dla => self.dla,
            EngineKind::Cpu => self.cpu,
            _ => PowerRail {
                idle_w: 0.0,
                peak_w: 0.0,
            },
        }
    }

    /// Average power of one engine at the given utilization (0–1).
    pub fn engine_power(&self, e: EngineKind, utilization: f64) -> f64 {
        let r = self.rail(e);
        r.idle_w + (r.peak_w - r.idle_w) * utilization.clamp(0.0, 1.0)
    }

    /// Total SoC power for a set of engine utilizations.
    pub fn total_power(&self, utils: &[(EngineKind, f64)]) -> f64 {
        self.soc_static_w
            + utils
                .iter()
                .map(|&(e, u)| self.engine_power(e, u))
                .sum::<f64>()
    }

    /// Energy per frame in joules given power (W) and throughput (FPS).
    pub fn energy_per_frame(power_w: f64, fps: f64) -> f64 {
        if fps <= 0.0 {
            f64::INFINITY
        } else {
            power_w / fps
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_scales_power() {
        let m = PowerModel::orin();
        let idle = m.engine_power(EngineKind::Gpu, 0.0);
        let half = m.engine_power(EngineKind::Gpu, 0.5);
        let full = m.engine_power(EngineKind::Gpu, 1.0);
        assert!(idle < half && half < full);
        assert_eq!(full, 16.0);
    }

    #[test]
    fn dla_more_efficient_than_gpu() {
        let m = PowerModel::orin();
        assert!(m.engine_power(EngineKind::Dla, 1.0) < m.engine_power(EngineKind::Gpu, 0.3));
    }

    #[test]
    fn total_includes_static() {
        let m = PowerModel::orin();
        let p = m.total_power(&[(EngineKind::Gpu, 0.0), (EngineKind::Dla, 0.0)]);
        assert!(p > m.soc_static_w);
    }

    #[test]
    fn energy_per_frame_math() {
        assert!((PowerModel::energy_per_frame(15.0, 150.0) - 0.1).abs() < 1e-12);
        assert!(PowerModel::energy_per_frame(15.0, 0.0).is_infinite());
    }

    #[test]
    fn soc_name_selects_the_rail_table() {
        let x = PowerModel::for_soc(&crate::hw::xavier());
        let o = PowerModel::for_soc(&crate::hw::orin());
        assert_eq!(x.soc_static_w, PowerModel::xavier().soc_static_w);
        assert_eq!(o.soc_static_w, PowerModel::orin().soc_static_w);
        // same 30 W class, different curves: Xavier idles hotter and
        // peaks lower than Orin on every rail
        assert!(x.gpu.idle_w > o.gpu.idle_w && x.gpu.peak_w < o.gpu.peak_w);
    }

    #[test]
    fn utilization_clamped() {
        let m = PowerModel::orin();
        assert_eq!(
            m.engine_power(EngineKind::Gpu, 1.5),
            m.engine_power(EngineKind::Gpu, 1.0)
        );
    }
}
