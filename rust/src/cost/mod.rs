//! Cost modelling: per-layer FLOPs/bytes, per-engine latency, PCCS-style
//! memory-contention slowdown, and a tegrastats-like power model.

pub mod contention;
pub mod flops;
pub mod latency;
pub mod power;

pub use flops::{layer_cost, LayerCost};
pub use latency::{graph_latency, layer_latency, segment_latency, LatencyModel};
