//! The serving facade — the crate's single execution entry point.
//!
//! A [`Session`] binds a declarative [`PipelineSpec`] (*what* runs) to an
//! [`InferenceBackend`] (*how* it runs) after fail-fast validation, and
//! [`Session::run`] drives the coordinator to completion. Build one with
//! [`Session::builder`]:
//!
//! ```no_run
//! use edgepipe::pipeline::router::RoutePolicy;
//! use edgepipe::pipeline::spec::InstanceSpec;
//! use edgepipe::session::Session;
//!
//! let report = Session::builder()
//!     .instance(InstanceSpec::new("gan", "gen_cropping").scored(true))
//!     .instance(InstanceSpec::new("yolo", "yolo_lite"))
//!     .route(RoutePolicy::Fanout)
//!     .frames(64)
//!     .build()?
//!     .run()?;
//! println!("{:.1} fps", report.total_fps());
//! # Ok::<(), edgepipe::Error>(())
//! ```
//!
//! The four historical `Workload` arms are presets lowered through
//! [`PipelineBuilder::workload`] (equivalently `Workload::spec(variant)`);
//! arbitrary instance mixes — three GANs, five detectors, anything the
//! backend can serve — go through [`PipelineBuilder::instance`].
//!
//! The serving hot path behind [`Session::run`] is zero-copy: pixel
//! planes are `Arc`-shared [`crate::pipeline::plane::FramePlane`]s
//! recycled through a [`crate::pipeline::plane::PlanePool`], and workers
//! execute whole batches as single dispatches
//! ([`crate::pipeline::backend::ModelRunner::execute_batch`]) under an
//! exclusive engine lease from the run's shared
//! [`crate::pipeline::engines::EngineArbiter`] — pinning two instances to
//! the same unit serializes them, split placements contend through shared
//! DRAM, and the resulting per-engine utilization/idle-gap statistics ride
//! on the [`crate::pipeline::driver::PipelineReport`]. See the
//! [`crate::pipeline::driver`] module docs for the full data-path
//! contract.

use crate::config::{GanVariant, PipelineConfig, Workload};
use crate::error::Result;
use crate::pipeline::backend::InferenceBackend;
#[cfg(feature = "pjrt")]
use crate::pipeline::backend::PjrtBackend;
use crate::pipeline::batcher::BatchPolicy;
use crate::pipeline::driver::{self, PipelineReport};
use crate::pipeline::router::RoutePolicy;
use crate::pipeline::spec::{InstanceSpec, PipelineSpec, SourceSpec};
use std::sync::Arc;

/// A validated, runnable pipeline: spec + backend.
pub struct Session {
    spec: PipelineSpec,
    backend: Arc<dyn InferenceBackend>,
}

impl Session {
    /// Start composing a pipeline.
    pub fn builder() -> PipelineBuilder {
        PipelineBuilder::new()
    }

    /// The validated spec this session runs.
    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    /// Which backend executes the instances (`pjrt`, `sim`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Stream all frames through the pipeline and report.
    pub fn run(&self) -> Result<PipelineReport> {
        driver::execute(&self.spec, &self.backend)
    }

    /// [`Session::run`] with an optional frame-lifecycle stage accumulator
    /// (usually `Some(Arc::clone(&hub.stages))` for an
    /// [`crate::obs::ObsHub`]): every completed frame copy's stage stamps
    /// fold into the accumulator and the report carries the per-stage
    /// latency breakdown in [`PipelineReport::stages`]. `None` is exactly
    /// [`Session::run`].
    pub fn run_observed(
        &self,
        stages: Option<Arc<crate::obs::StageAccum>>,
    ) -> Result<PipelineReport> {
        driver::execute_observed(&self.spec, &self.backend, stages)
    }

    /// Decompose into the validated spec and the bound backend — the
    /// handoff the long-running [`crate::serve`] front-end uses: it keeps
    /// the backend for the whole serve and swaps *specs* across
    /// drain-and-switch re-plans.
    pub fn into_parts(self) -> (PipelineSpec, Arc<dyn InferenceBackend>) {
        (self.spec, self.backend)
    }
}

/// Composable builder for [`Session`]s.
pub struct PipelineBuilder {
    spec: PipelineSpec,
    backend: Option<Arc<dyn InferenceBackend>>,
    artifact_dir: String,
}

impl Default for PipelineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl PipelineBuilder {
    pub fn new() -> Self {
        PipelineBuilder {
            spec: PipelineSpec::default(),
            backend: None,
            artifact_dir: "artifacts".to_string(),
        }
    }

    /// Lower a full [`PipelineConfig`] (CLI flags / JSON file) into a
    /// builder: explicit `instances` win over the `workload` preset, and
    /// the artifact directory seeds the default PJRT backend.
    pub fn from_config(cfg: &PipelineConfig) -> Self {
        PipelineBuilder {
            spec: cfg.spec(),
            backend: None,
            artifact_dir: cfg.artifact_dir.clone(),
        }
    }

    /// Append one model instance.
    pub fn instance(mut self, inst: InstanceSpec) -> Self {
        self.spec.instances.push(inst);
        self
    }

    /// Replace the instance set and route with a `Workload` preset
    /// (sugar: the four paper arms lowered via `Workload::spec`).
    pub fn workload(mut self, workload: Workload, variant: GanVariant) -> Self {
        let preset = workload.spec(variant);
        self.spec.instances = preset.instances;
        self.spec.route = preset.route;
        self
    }

    /// Replace the instance set and route with the auto-placement
    /// planner's winning candidate for `request` (plan → spec → session:
    /// serving consumes a *searched* allocation instead of a hand-written
    /// preset). Stream shape set on the builder (`frames`, `streams`,
    /// `queue_depth`, `seed`) is preserved; fails when no feasible
    /// placement exists (every candidate rejected by the DLA-fallback or
    /// latency-budget constraints).
    pub fn auto_place(mut self, request: &crate::placement::PlacementRequest) -> Result<Self> {
        let outcome = crate::placement::plan(request)?;
        self.spec.instances = outcome.spec.instances;
        self.spec.route = outcome.spec.route;
        Ok(self)
    }

    /// Set the routing policy.
    pub fn route(mut self, route: RoutePolicy) -> Self {
        self.spec.route = route;
        self
    }

    /// Apply one batching policy to every instance added so far.
    pub fn batch(mut self, batch: BatchPolicy) -> Self {
        for inst in &mut self.spec.instances {
            inst.batch = batch;
        }
        self
    }

    pub fn frames(mut self, frames: usize) -> Self {
        self.spec.frames = frames;
        self
    }

    pub fn streams(mut self, streams: usize) -> Self {
        self.spec.streams = streams;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.spec.queue_depth = depth;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.spec.seed = seed;
        self
    }

    /// Select the acquisition front-end (phantom slices, or undersampled
    /// k-space reconstructed in-pipeline before the model chain).
    pub fn source(mut self, source: SourceSpec) -> Self {
        self.spec.source = source;
        self
    }

    /// Artifact directory for the default PJRT backend (ignored when an
    /// explicit backend is set).
    pub fn artifact_dir(mut self, dir: impl Into<String>) -> Self {
        self.artifact_dir = dir.into();
        self
    }

    /// Plug in an execution backend (default: [`PjrtBackend`] over the
    /// artifact directory).
    pub fn backend(mut self, backend: Arc<dyn InferenceBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Validate the spec, bind the backend, and fail fast on anything the
    /// backend cannot serve — all before a single thread spawns.
    pub fn build(self) -> Result<Session> {
        let PipelineBuilder {
            spec,
            backend,
            artifact_dir,
        } = self;
        spec.validate()?;
        #[cfg(feature = "pjrt")]
        let backend: Arc<dyn InferenceBackend> =
            backend.unwrap_or_else(|| Arc::new(PjrtBackend::new(artifact_dir.as_str())));
        #[cfg(not(feature = "pjrt"))]
        let backend: Arc<dyn InferenceBackend> = {
            let _ = artifact_dir;
            backend.ok_or_else(|| {
                crate::error::Error::Config(
                    "no inference backend set and the `pjrt` feature is disabled; \
                     pass .backend(Arc::new(SimBackend::new(...)))"
                        .into(),
                )
            })?
        };
        for inst in &spec.instances {
            backend.prepare(inst)?;
        }
        Ok(Session { spec, backend })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::orin;
    use crate::pipeline::backend::SimBackend;

    fn sim() -> Arc<dyn InferenceBackend> {
        Arc::new(SimBackend::new(orin()).with_time_scale(0.0))
    }

    #[test]
    fn empty_builder_fails_fast() {
        let err = Session::builder().backend(sim()).build().unwrap_err();
        assert!(err.to_string().contains("no instances"));
    }

    #[test]
    fn unknown_artifact_fails_at_build_not_run() {
        let err = Session::builder()
            .instance(InstanceSpec::new("x", "not_a_model"))
            .backend(sim())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unknown artifact"));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn missing_pjrt_artifacts_fail_at_build() {
        let err = Session::builder()
            .instance(InstanceSpec::new("gan", "gen_cropping"))
            .artifact_dir("/nonexistent")
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn auto_place_binds_a_planned_spec() {
        use crate::dla::DlaVersion;
        use crate::placement::PlacementRequest;
        let req =
            PlacementRequest::new(crate::hw::xavier(), DlaVersion::V1).dla_resident_gans();
        let session = Session::builder()
            .auto_place(&req)
            .unwrap()
            .frames(8)
            .backend(sim())
            .build()
            .unwrap();
        // planner output: two DLA-resident GANs plus the GPU detector;
        // builder-level stream shape wins over the planned window
        assert_eq!(session.spec().instances.len(), 3);
        assert_eq!(session.spec().frames, 8);
        assert!(session
            .spec()
            .instances
            .iter()
            .filter(|i| i.artifact.starts_with("gen_"))
            .all(|i| i.engine == crate::hw::EngineKind::Dla));
    }

    #[test]
    fn workload_preset_populates_builder() {
        let session = Session::builder()
            .workload(Workload::GanPlusYolo, GanVariant::Cropping)
            .frames(8)
            .backend(sim())
            .build()
            .unwrap();
        assert_eq!(session.spec().instances.len(), 2);
        assert_eq!(session.spec().route, RoutePolicy::Fanout);
        assert_eq!(session.backend_name(), "sim");
    }
}
