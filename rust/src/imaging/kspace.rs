//! Undersampled multi-coil k-space acquisition of a ground-truth slice.
//!
//! Models the accelerated-MRI front door the paper's pipeline starts
//! *after*: the slice is weighted by SoS-normalized synthetic coil
//! sensitivity maps, transformed to k-space per coil ([`Fft2`]), and
//! undersampled to every R-th phase-encode row plus a wrapped
//! auto-calibration (ACS) band around DC. [`Acquisition::recon_zero_filled`]
//! and [`Acquisition::recon_grappa`] then reconstruct the image the
//! downstream GAN→YOLO chain consumes; the fully-sampled source slice is
//! retained as the recon-fidelity ground truth (the maps are normalized so
//! a fully-sampled root-sum-of-squares combine reproduces it exactly).
//! All per-frame buffers live in the struct — acquire/recon allocates
//! nothing after construction (the GRAPPA fit's per-band scratch aside).

// Per-frame acquisition path: a panic here kills the source thread.
#![deny(clippy::unwrap_used)]

use super::fft::Fft2;
use super::grappa::GrappaKernel;
use super::image::Image;
use crate::error::{Error, Result};

/// Tikhonov ridge for the GRAPPA calibration fit, relative to the mean
/// Gram diagonal.
pub const GRAPPA_LAMBDA_REL: f64 = 1e-4;

/// Smooth complex coil-sensitivity maps for `coils` channels placed on a
/// ring around an `n`×`n` slice (Gaussian magnitude falloff, linear
/// phase), normalized per pixel so `Σ_c |s_c|² = 1`. Returned coil-major
/// as split `(re, im)` planes of length `coils·n·n`.
pub fn coil_maps(n: usize, coils: usize) -> (Vec<f32>, Vec<f32>) {
    let plane = n * n;
    let mut map_re = vec![0.0f32; coils * plane];
    let mut map_im = vec![0.0f32; coils * plane];
    for c in 0..coils {
        let ang = 2.0 * std::f64::consts::PI * c as f64 / coils as f64;
        let cx = n as f64 / 2.0 + 0.45 * n as f64 * ang.cos();
        let cy = n as f64 / 2.0 + 0.45 * n as f64 * ang.sin();
        let width2 = (0.6 * n as f64) * (0.6 * n as f64);
        for y in 0..n {
            for x in 0..n {
                let d2 = ((x as f64 - cx) * (x as f64 - cx)
                    + (y as f64 - cy) * (y as f64 - cy))
                    / width2;
                let mag = (-d2).exp();
                let ph = 0.5 * std::f64::consts::PI
                    * (x as f64 * ang.cos() + y as f64 * ang.sin())
                    / n as f64;
                map_re[c * plane + y * n + x] = (mag * ph.cos()) as f32;
                map_im[c * plane + y * n + x] = (mag * ph.sin()) as f32;
            }
        }
    }
    // Per-pixel sum-of-squares normalization: RSS of a fully-sampled
    // acquisition reproduces the source slice. The Gaussian magnitude is
    // strictly positive, so the divisor never vanishes.
    for p in 0..plane {
        let mut sos = 0.0f64;
        for c in 0..coils {
            let re = map_re[c * plane + p] as f64;
            let im = map_im[c * plane + p] as f64;
            sos += re * re + im * im;
        }
        let inv = 1.0 / sos.sqrt();
        for c in 0..coils {
            map_re[c * plane + p] = (map_re[c * plane + p] as f64 * inv) as f32;
            map_im[c * plane + p] = (map_im[c * plane + p] as f64 * inv) as f32;
        }
    }
    (map_re, map_im)
}

/// Phase-encode row sampling mask: every `accel`-th row plus a wrapped
/// `acs_lines`-row calibration band around the DC row 0.
pub fn sample_mask(n: usize, accel: usize, acs_lines: usize) -> Vec<bool> {
    let mut mask = vec![false; n];
    let mut row = 0usize;
    while row < n {
        mask[row] = true;
        row += accel.max(1);
    }
    let half = (acs_lines / 2) as isize;
    for i in 0..acs_lines as isize {
        let r = (i - half).rem_euclid(n as isize) as usize;
        mask[r] = true;
    }
    mask
}

/// One stream's acquisition state: coil maps, sampling mask, FFT plan,
/// GRAPPA kernel and every per-frame scratch plane.
#[derive(Debug, Clone)]
pub struct Acquisition {
    n: usize,
    coils: usize,
    accel: usize,
    acs_lines: usize,
    fft: Fft2,
    map_re: Vec<f32>,
    map_im: Vec<f32>,
    mask: Vec<bool>,
    sampled_rows: usize,
    kernel: GrappaKernel,
    /// Fully-sampled source slice of the latest [`Self::acquire`] — the
    /// recon ground truth, and the bit-exact R=1 fast path.
    src: Vec<f32>,
    /// Acquired (undersampled) k-space, coil-major split planes.
    ks_re: Vec<f32>,
    ks_im: Vec<f32>,
    /// Recon scratch planes (k-space copies that get synthesized and
    /// inverse-transformed).
    work_re: Vec<f32>,
    work_im: Vec<f32>,
}

impl Acquisition {
    /// An acquisition of `n`×`n` slices (power of two) at acceleration
    /// `accel` (must divide `n`) with `acs_lines` calibration rows on
    /// `coils` channels.
    pub fn new(n: usize, accel: usize, acs_lines: usize, coils: usize) -> Result<Acquisition> {
        let fft = Fft2::new(n)?;
        if accel == 0 || n % accel != 0 {
            return Err(Error::Imaging(format!(
                "acceleration factor {accel} must be >= 1 and divide the slice size {n}"
            )));
        }
        if acs_lines > n {
            return Err(Error::Imaging(format!(
                "acs_lines {acs_lines} exceeds the {n} phase-encode rows"
            )));
        }
        if coils == 0 {
            return Err(Error::Imaging("coil count must be >= 1".into()));
        }
        let (map_re, map_im) = coil_maps(n, coils);
        let mask = sample_mask(n, accel, acs_lines);
        let sampled_rows = mask.iter().filter(|&&m| m).count();
        let kernel = GrappaKernel::new(coils, accel)?;
        let plane = n * n;
        Ok(Acquisition {
            n,
            coils,
            accel,
            acs_lines,
            fft,
            map_re,
            map_im,
            mask,
            sampled_rows,
            kernel,
            src: vec![0.0; plane],
            ks_re: vec![0.0; coils * plane],
            ks_im: vec![0.0; coils * plane],
            work_re: vec![0.0; coils * plane],
            work_im: vec![0.0; coils * plane],
        })
    }

    /// Slice side length.
    pub fn size(&self) -> usize {
        self.n
    }

    /// Acceleration factor R.
    pub fn accel(&self) -> usize {
        self.accel
    }

    /// Calibration-band width in rows.
    pub fn acs_lines(&self) -> usize {
        self.acs_lines
    }

    /// Receive-channel count.
    pub fn coils(&self) -> usize {
        self.coils
    }

    /// The row sampling mask.
    pub fn mask(&self) -> &[bool] {
        &self.mask
    }

    /// Sampled phase-encode rows per frame.
    pub fn sampled_rows(&self) -> usize {
        self.sampled_rows
    }

    /// The fully-sampled source slice of the latest [`Self::acquire`] —
    /// the ground truth recon fidelity is scored against.
    pub fn ground_truth(&self) -> &[f32] {
        &self.src
    }

    /// Acquire one slice: weight by the coil maps, transform each coil to
    /// k-space, and zero every phase-encode row the mask excludes.
    pub fn acquire(&mut self, img: &Image) -> Result<()> {
        if img.width != self.n || img.height != self.n || img.data.len() != self.n * self.n {
            return Err(Error::Imaging(format!(
                "acquisition expects a {0}x{0} slice, got {1}x{2}",
                self.n, img.width, img.height
            )));
        }
        self.src.copy_from_slice(&img.data);
        let plane = self.n * self.n;
        for c in 0..self.coils {
            let o = c * plane;
            for p in 0..plane {
                let v = img.data[p];
                self.ks_re[o + p] = self.map_re[o + p] * v;
                self.ks_im[o + p] = self.map_im[o + p] * v;
            }
            self.fft.fft2(
                &mut self.ks_re[o..o + plane],
                &mut self.ks_im[o..o + plane],
            )?;
            for (row, &keep) in self.mask.iter().enumerate() {
                if keep {
                    continue;
                }
                let lo = o + row * self.n;
                for v in &mut self.ks_re[lo..lo + self.n] {
                    *v = 0.0;
                }
                for v in &mut self.ks_im[lo..lo + self.n] {
                    *v = 0.0;
                }
            }
        }
        Ok(())
    }

    fn check_out(&self, out: &[f32]) -> Result<()> {
        if out.len() != self.n * self.n {
            return Err(Error::Imaging(format!(
                "recon output length {} != {}",
                out.len(),
                self.n * self.n
            )));
        }
        Ok(())
    }

    /// Zero-filled baseline: inverse-transform the undersampled k-space
    /// directly, scaled by `n / sampled_rows` to restore the DC
    /// amplitude, and combine by root-sum-of-squares. At R=1 this is the
    /// bit-exact fully-sampled fast path.
    pub fn recon_zero_filled(&mut self, out: &mut [f32]) -> Result<()> {
        self.check_out(out)?;
        if self.accel == 1 {
            out.copy_from_slice(&self.src);
            return Ok(());
        }
        let scale = self.n as f32 / self.sampled_rows as f32;
        self.work_re.copy_from_slice(&self.ks_re);
        self.work_im.copy_from_slice(&self.ks_im);
        for v in self.work_re.iter_mut() {
            *v *= scale;
        }
        for v in self.work_im.iter_mut() {
            *v *= scale;
        }
        self.combine_rss(out)
    }

    /// GRAPPA reconstruction: autocalibrate the kernel on the ACS band of
    /// this acquisition, synthesize the missing rows, inverse-transform
    /// and combine by root-sum-of-squares. At R=1 this is the bit-exact
    /// fully-sampled fast path.
    pub fn recon_grappa(&mut self, out: &mut [f32]) -> Result<()> {
        self.check_out(out)?;
        if self.accel == 1 {
            out.copy_from_slice(&self.src);
            return Ok(());
        }
        self.kernel
            .fit(&self.ks_re, &self.ks_im, &self.mask, GRAPPA_LAMBDA_REL)?;
        self.work_re.copy_from_slice(&self.ks_re);
        self.work_im.copy_from_slice(&self.ks_im);
        self.kernel
            .apply(&mut self.work_re, &mut self.work_im, &self.mask)?;
        self.combine_rss(out)
    }

    /// Inverse-transform every coil's work plane and combine them into
    /// `out` by root-sum-of-squares, clamped to `[0, 1]`.
    fn combine_rss(&mut self, out: &mut [f32]) -> Result<()> {
        let plane = self.n * self.n;
        for c in 0..self.coils {
            let o = c * plane;
            self.fft.ifft2(
                &mut self.work_re[o..o + plane],
                &mut self.work_im[o..o + plane],
            )?;
        }
        for (p, o) in out.iter_mut().enumerate() {
            let mut sos = 0.0f64;
            for c in 0..self.coils {
                let re = self.work_re[c * plane + p] as f64;
                let im = self.work_im[c * plane + p] as f64;
                sos += re * re + im * im;
            }
            *o = (sos.sqrt() as f32).clamp(0.0, 1.0);
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::imaging::phantom::{paired_sample, PhantomConfig};
    use crate::imaging::Image;
    use crate::util::rng::Rng;

    fn psnr01(a: &[f32], b: &[f32], n: usize) -> f64 {
        let ia = Image::from_data(n, n, a.to_vec()).unwrap();
        let ib = Image::from_data(n, n, b.to_vec()).unwrap();
        crate::imaging::metrics::psnr(&ia, &ib).unwrap()
    }

    #[test]
    fn maps_are_sos_normalized() {
        let (re, im) = coil_maps(16, 4);
        let plane = 16 * 16;
        for p in 0..plane {
            let sos: f64 = (0..4)
                .map(|c| {
                    let r = re[c * plane + p] as f64;
                    let i = im[c * plane + p] as f64;
                    r * r + i * i
                })
                .sum();
            assert!((sos - 1.0).abs() < 1e-5, "pixel {p}: sos {sos}");
        }
    }

    #[test]
    fn mask_has_lattice_plus_wrapped_acs_band() {
        let m = sample_mask(64, 4, 16);
        assert!(m[0] && m[4] && m[60]);
        // wrapped band: rows -8..7 around DC
        assert!(m[56] && m[63] && m[7]);
        assert!(!m[9] && !m[33]);
        let kept = m.iter().filter(|&&b| b).count();
        // 16 lattice rows + 16 ACS rows, 4 ACS rows already on the lattice
        assert_eq!(kept, 28);
    }

    #[test]
    fn invalid_geometry_is_rejected() {
        assert!(Acquisition::new(48, 2, 8, 4).is_err(), "not a power of two");
        assert!(Acquisition::new(64, 3, 8, 4).is_err(), "R must divide n");
        assert!(Acquisition::new(64, 2, 80, 4).is_err(), "ACS wider than n");
        assert!(Acquisition::new(64, 2, 8, 0).is_err(), "no coils");
    }

    #[test]
    fn r1_recon_is_bit_exact() {
        let cfg = PhantomConfig::default();
        let mut rng = Rng::new(11);
        let s = paired_sample(&cfg, &mut rng);
        let n = cfg.size;
        let mut acq = Acquisition::new(n, 1, 0, 4).unwrap();
        acq.acquire(&s.ct).unwrap();
        let mut zf = vec![0.0f32; n * n];
        let mut gr = vec![0.0f32; n * n];
        acq.recon_zero_filled(&mut zf).unwrap();
        acq.recon_grappa(&mut gr).unwrap();
        assert_eq!(zf, s.ct.data);
        assert_eq!(gr, s.ct.data);
    }

    #[test]
    fn grappa_beats_zero_filled_at_r4() {
        let cfg = PhantomConfig::default();
        let mut rng = Rng::new(5);
        let s = paired_sample(&cfg, &mut rng);
        let n = cfg.size;
        let mut acq = Acquisition::new(n, 4, 16, 4).unwrap();
        acq.acquire(&s.ct).unwrap();
        let mut zf = vec![0.0f32; n * n];
        let mut gr = vec![0.0f32; n * n];
        acq.recon_zero_filled(&mut zf).unwrap();
        acq.recon_grappa(&mut gr).unwrap();
        let p_zf = psnr01(&s.ct.data, &zf, n);
        let p_gr = psnr01(&s.ct.data, &gr, n);
        assert!(
            p_gr > p_zf + 3.0,
            "grappa {p_gr:.2} dB must clearly beat zero-filled {p_zf:.2} dB"
        );
    }
}
