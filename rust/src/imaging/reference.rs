//! Scalar reference implementations of the imaging kernels.
//!
//! These are the original single-threaded, per-pixel clamped-border loops
//! the optimized kernels replaced. They stay in-tree for two jobs:
//!
//! 1. **Equivalence oracles** — `tests/prop_imaging.rs` asserts the
//!    optimized kernels match these on random images (bit-exact for
//!    median/histeq/LZW/DCT, within tolerance for the float reductions).
//! 2. **Bench baselines** — `benches/hotpath.rs` times each optimized
//!    kernel against its scalar counterpart here, so the recorded
//!    `speedup_vs_scalar` rates are measured, not estimated.
//!
//! Keep these slow-and-obvious: clarity is the point. Any behavioral
//! change to an optimized kernel must land here too, or the property
//! tests will (correctly) fail.

use super::image::Image;
use super::sobel::Gradient;
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Scalar 3×3 Sobel: per-pixel clamped gathers, no interior split.
pub fn sobel(img: &Image) -> Gradient {
    let (w, h) = (img.width, img.height);
    let mut magnitude = Image::zeros(w, h);
    let mut direction = vec![0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let p = |dx: isize, dy: isize| img.get_clamped(x as isize + dx, y as isize + dy);
            let gx = -p(-1, -1) - 2.0 * p(-1, 0) - p(-1, 1) + p(1, -1) + 2.0 * p(1, 0) + p(1, 1);
            let gy = -p(-1, -1) - 2.0 * p(0, -1) - p(1, -1) + p(-1, 1) + 2.0 * p(0, 1) + p(1, 1);
            magnitude.set(x, y, (gx * gx + gy * gy).sqrt());
            direction[y * w + x] = gy.atan2(gx);
        }
    }
    Gradient {
        magnitude,
        direction,
    }
}

/// Scalar 5×5 Gaussian blur (sigma ≈ 1.0), separable, clamped everywhere.
pub fn gaussian5(img: &Image) -> Image {
    const K: [f32; 5] = [1.0, 4.0, 6.0, 4.0, 1.0]; // binomial, sum 16
    let (w, h) = (img.width, img.height);
    let mut tmp = Image::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let mut s = 0.0;
            for (i, &k) in K.iter().enumerate() {
                s += k * img.get_clamped(x as isize + i as isize - 2, y as isize);
            }
            tmp.set(x, y, s / 16.0);
        }
    }
    let mut out = Image::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let mut s = 0.0;
            for (i, &k) in K.iter().enumerate() {
                s += k * tmp.get_clamped(x as isize, y as isize + i as isize - 2);
            }
            out.set(x, y, s / 16.0);
        }
    }
    out
}

/// Scalar Canny: smooth → sobel → NMS → double threshold → BFS hysteresis.
pub fn canny(img: &Image, low: f32, high: f32) -> Image {
    assert!(low <= high, "low threshold must be <= high");
    let smoothed = gaussian5(img);
    let g = sobel(&smoothed);
    let (w, h) = (img.width, img.height);

    let mut nms = Image::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let m = g.magnitude.get(x, y);
            if m == 0.0 {
                continue;
            }
            let angle = g.direction[y * w + x];
            let deg = angle.to_degrees();
            let deg = if deg < 0.0 { deg + 180.0 } else { deg };
            let (dx, dy): (isize, isize) = if !(22.5..157.5).contains(&deg) {
                (1, 0)
            } else if deg < 67.5 {
                (1, 1)
            } else if deg < 112.5 {
                (0, 1)
            } else {
                (-1, 1)
            };
            let a = g.magnitude.get_clamped(x as isize + dx, y as isize + dy);
            let b = g.magnitude.get_clamped(x as isize - dx, y as isize - dy);
            if m >= a && m >= b {
                nms.set(x, y, m);
            }
        }
    }

    const WEAK: f32 = 0.5;
    const STRONG: f32 = 1.0;
    let mut marks = Image::zeros(w, h);
    let mut stack = Vec::new();
    for y in 0..h {
        for x in 0..w {
            let m = nms.get(x, y);
            if m >= high {
                marks.set(x, y, STRONG);
                stack.push((x, y));
            } else if m >= low {
                marks.set(x, y, WEAK);
            }
        }
    }
    while let Some((x, y)) = stack.pop() {
        for dy in -1isize..=1 {
            for dx in -1isize..=1 {
                let nx = x as isize + dx;
                let ny = y as isize + dy;
                if nx < 0 || ny < 0 || nx >= w as isize || ny >= h as isize {
                    continue;
                }
                let (nx, ny) = (nx as usize, ny as usize);
                if marks.get(nx, ny) == WEAK {
                    marks.set(nx, ny, STRONG);
                    stack.push((nx, ny));
                }
            }
        }
    }
    for v in &mut marks.data {
        *v = if *v == STRONG { 1.0 } else { 0.0 };
    }
    marks
}

/// Scalar k×k median — per-pixel window gather + partial sort.
pub fn median_k(img: &Image, k: usize) -> Image {
    assert!(k % 2 == 1 && k >= 1, "kernel must be odd");
    let r = (k / 2) as isize;
    let mut out = Image::zeros(img.width, img.height);
    let mut buf = Vec::with_capacity(k * k);
    for y in 0..img.height {
        for x in 0..img.width {
            buf.clear();
            for dy in -r..=r {
                for dx in -r..=r {
                    buf.push(img.get_clamped(x as isize + dx, y as isize + dy));
                }
            }
            let mid = buf.len() / 2;
            buf.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
            out.set(x, y, buf[mid]);
        }
    }
    out
}

/// Scalar histogram equalization (clones the LUT application loop of the
/// original, including its full-image copy).
pub fn equalize(img: &Image) -> Image {
    use super::histeq::{histogram, BINS};
    let hist = histogram(img);
    let n = img.data.len() as u64;
    let mut cdf = [0u64; BINS];
    let mut acc = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        acc += c as u64;
        cdf[i] = acc;
    }
    let cdf_min = cdf.iter().copied().find(|&c| c > 0).unwrap_or(0);
    let denom = (n - cdf_min).max(1) as f32;

    let mut lut = [0f32; BINS];
    for i in 0..BINS {
        lut[i] = ((cdf[i].saturating_sub(cdf_min)) as f32 / denom).clamp(0.0, 1.0);
    }
    let mut out = img.clone();
    for v in &mut out.data {
        let b = ((v.clamp(0.0, 1.0) * 255.0).round() as usize).min(BINS - 1);
        *v = lut[b];
    }
    out
}

/// Scalar SSIM: per-window 5-accumulator loop (8×8 windows, stride 4).
pub fn ssim(original: &Image, generated: &Image) -> Result<f64> {
    if original.width != generated.width || original.height != generated.height {
        return Err(Error::Imaging(format!(
            "dimension mismatch: {}x{} vs {}x{}",
            original.width, original.height, generated.width, generated.height
        )));
    }
    const WIN: usize = 8;
    const STRIDE: usize = 4;
    let l = 255.0f64;
    let c1 = (0.01 * l) * (0.01 * l);
    let c2 = (0.03 * l) * (0.03 * l);
    let (w, h) = (original.width, original.height);
    if w < WIN || h < WIN {
        return Err(Error::Imaging(format!(
            "image {w}x{h} smaller than ssim window {WIN}"
        )));
    }
    let mut total = 0.0;
    let mut count = 0usize;
    let mut y = 0;
    while y + WIN <= h {
        let mut x = 0;
        while x + WIN <= w {
            let (mut so, mut sg, mut soo, mut sgg, mut sog) = (0.0, 0.0, 0.0, 0.0, 0.0);
            for dy in 0..WIN {
                for dx in 0..WIN {
                    let o = original.get(x + dx, y + dy) as f64 * 255.0;
                    let g = generated.get(x + dx, y + dy) as f64 * 255.0;
                    so += o;
                    sg += g;
                    soo += o * o;
                    sgg += g * g;
                    sog += o * g;
                }
            }
            let n = (WIN * WIN) as f64;
            let mo = so / n;
            let mg = sg / n;
            let vo = (soo / n - mo * mo).max(0.0);
            let vg = (sgg / n - mg * mg).max(0.0);
            let cov = sog / n - mo * mg;
            let s = ((2.0 * mo * mg + c1) * (2.0 * cov + c2))
                / ((mo * mo + mg * mg + c1) * (vo + vg + c2));
            total += s;
            count += 1;
            x += STRIDE;
        }
        y += STRIDE;
    }
    Ok(total / count as f64)
}

/// Scalar blockwise 8×8 DCT via per-pixel `get`/`set` block copies.
pub fn dct_image(img: &Image) -> Image {
    use super::dct::dct8_block;
    const N: usize = 8;
    assert!(
        img.width % N == 0 && img.height % N == 0,
        "dims must be 8-aligned"
    );
    let mut out = Image::zeros(img.width, img.height);
    for by in (0..img.height).step_by(N) {
        for bx in (0..img.width).step_by(N) {
            let mut block = [0f32; 64];
            for y in 0..N {
                for x in 0..N {
                    block[y * N + x] = img.get(bx + x, by + y);
                }
            }
            let coeffs = dct8_block(&block);
            for y in 0..N {
                for x in 0..N {
                    out.set(bx + x, by + y, coeffs[y * N + x]);
                }
            }
        }
    }
    out
}

/// Scalar 1D radix-2 FFT line — the same butterfly DAG and f64→f32
/// twiddle tables as `imaging::fft::FftPlan::transform`, with the tables
/// rebuilt on every call. `tests/prop_kspace.rs` asserts the planned
/// transform matches this bit-exactly at any thread count.
fn fft_line(re: &mut [f32], im: &mut [f32], inverse: bool) {
    let n = re.len();
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = ((i as u32).reverse_bits() >> (32 - bits)) as usize;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut tw_re = vec![0.0f32; n / 2];
    let mut tw_im = vec![0.0f32; n / 2];
    for k in 0..n / 2 {
        let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
        tw_re[k] = ang.cos() as f32;
        tw_im[k] = ang.sin() as f32;
    }
    let mut len = 2usize;
    while len <= n {
        let half = len / 2;
        let step = n / len;
        let mut base = 0usize;
        while base < n {
            let mut k = 0usize;
            for off in 0..half {
                let wr = tw_re[k];
                let wi = if inverse { -tw_im[k] } else { tw_im[k] };
                let a = base + off;
                let b = a + half;
                let xr = re[b] * wr - im[b] * wi;
                let xi = re[b] * wi + im[b] * wr;
                re[b] = re[a] - xr;
                im[b] = im[a] - xi;
                re[a] += xr;
                im[a] += xi;
                k += step;
            }
            base += len;
        }
        len *= 2;
    }
    if inverse {
        let s = 1.0 / n as f32;
        for v in re.iter_mut() {
            *v *= s;
        }
        for v in im.iter_mut() {
            *v *= s;
        }
    }
}

fn fft2_pass(n: usize, re: &mut [f32], im: &mut [f32], inverse: bool) -> Result<()> {
    if n < 2 || !n.is_power_of_two() || re.len() != n * n || im.len() != n * n {
        return Err(Error::Imaging(format!(
            "reference fft2: bad geometry n={n}, planes {}/{}",
            re.len(),
            im.len()
        )));
    }
    let transpose = |a: &mut [f32]| {
        for y in 0..n {
            for x in (y + 1)..n {
                a.swap(y * n + x, x * n + y);
            }
        }
    };
    for _ in 0..2 {
        for (rr, ir) in re.chunks_mut(n).zip(im.chunks_mut(n)) {
            fft_line(rr, ir, inverse);
        }
        transpose(re);
        transpose(im);
    }
    Ok(())
}

/// Scalar 2D FFT oracle — serial rows/transpose passes, bit-identical to
/// `imaging::fft::Fft2::fft2`.
pub fn fft2(n: usize, re: &mut [f32], im: &mut [f32]) -> Result<()> {
    fft2_pass(n, re, im, false)
}

/// Scalar inverse 2D FFT oracle, bit-identical to
/// `imaging::fft::Fft2::ifft2`.
pub fn ifft2(n: usize, re: &mut [f32], im: &mut [f32]) -> Result<()> {
    fft2_pass(n, re, im, true)
}

type C = (f64, f64);

fn cadd(a: C, b: C) -> C {
    (a.0 + b.0, a.1 + b.1)
}

fn csub(a: C, b: C) -> C {
    (a.0 - b.0, a.1 - b.1)
}

fn cmul(a: C, b: C) -> C {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

fn conj(a: C) -> C {
    (a.0, -a.1)
}

/// Scalar GRAPPA oracle: serial normal-equation fit (per offset `d`) and
/// missing-row synthesis over one undersampled multi-coil k-space
/// (`coils` split planes of `n*n`, coil-major). Returns the synthesized
/// planes; geometry and tap order mirror `imaging::grappa::GrappaKernel`.
pub fn grappa_recon(
    n: usize,
    coils: usize,
    accel: usize,
    ks_re: &[f32],
    ks_im: &[f32],
    mask: &[bool],
    lambda_rel: f64,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let plane = n * n;
    if coils == 0 || accel == 0 || mask.len() != n || ks_re.len() != coils * plane {
        return Err(Error::Imaging("reference grappa: bad geometry".into()));
    }
    let mut out_re = ks_re.to_vec();
    let mut out_im = ks_im.to_vec();
    if accel < 2 {
        return Ok((out_re, out_im));
    }
    let dim = 6 * coils;
    let at = |c: usize, row: usize, x: usize| -> C {
        let i = c * plane + row * n + x;
        (ks_re[i] as f64, ks_im[i] as f64)
    };
    let block = |rows: [usize; 2], x: usize| -> Vec<C> {
        let mut blk = Vec::with_capacity(dim);
        for row in rows {
            for dx in [n - 1, 0, 1] {
                let xc = (x + dx) % n;
                for c in 0..coils {
                    blk.push(at(c, row, xc));
                }
            }
        }
        blk
    };
    for d in 1..accel {
        // Normal equations over every calibratable (t, x) sample.
        let mut gram = vec![(0.0, 0.0); dim * dim];
        let mut rhs = vec![(0.0, 0.0); dim * coils];
        let mut count = 0usize;
        for t in 0..n {
            let lo = (t + n - d) % n;
            let hi = (lo + accel) % n;
            if !(mask[t] && mask[lo] && mask[hi]) {
                continue;
            }
            for x in 0..n {
                let blk = block([lo, hi], x);
                for j in 0..dim {
                    let a = conj(blk[j]);
                    for k in 0..dim {
                        gram[j * dim + k] = cadd(gram[j * dim + k], cmul(a, blk[k]));
                    }
                    for c in 0..coils {
                        rhs[j * coils + c] = cadd(rhs[j * coils + c], cmul(a, at(c, t, x)));
                    }
                }
                count += 1;
            }
        }
        if count == 0 {
            return Err(Error::Imaging(format!(
                "reference grappa: no calibration rows for offset {d}"
            )));
        }
        let trace: f64 = (0..dim).map(|j| gram[j * dim + j].0).sum();
        let lam = lambda_rel * trace / dim as f64;
        for j in 0..dim {
            gram[j * dim + j].0 += lam;
        }
        // Complex Gauss–Jordan with partial pivoting; solution in rhs.
        for col in 0..dim {
            let pivot = (col..dim)
                .max_by(|&a, &b| {
                    let ma = gram[a * dim + col];
                    let mb = gram[b * dim + col];
                    (ma.0 * ma.0 + ma.1 * ma.1).total_cmp(&(mb.0 * mb.0 + mb.1 * mb.1))
                })
                .unwrap_or(col);
            let p = gram[pivot * dim + col];
            if p.0 * p.0 + p.1 * p.1 <= f64::MIN_POSITIVE {
                return Err(Error::Imaging(format!(
                    "reference grappa: singular system at column {col}"
                )));
            }
            if pivot != col {
                for k in 0..dim {
                    gram.swap(pivot * dim + k, col * dim + k);
                }
                for c in 0..coils {
                    rhs.swap(pivot * coils + c, col * coils + c);
                }
            }
            let inv = 1.0 / (p.0 * p.0 + p.1 * p.1);
            let s = (p.0 * inv, -p.1 * inv);
            for k in 0..dim {
                gram[col * dim + k] = cmul(gram[col * dim + k], s);
            }
            for c in 0..coils {
                rhs[col * coils + c] = cmul(rhs[col * coils + c], s);
            }
            for r in 0..dim {
                if r == col {
                    continue;
                }
                let f = gram[r * dim + col];
                if f == (0.0, 0.0) {
                    continue;
                }
                for k in 0..dim {
                    gram[r * dim + k] = csub(gram[r * dim + k], cmul(f, gram[col * dim + k]));
                }
                for c in 0..coils {
                    rhs[r * coils + c] = csub(rhs[r * coils + c], cmul(f, rhs[col * coils + c]));
                }
            }
        }
        // Synthesize the missing rows at this offset from sampled rows.
        for s in 0..n {
            if !mask[s] {
                continue;
            }
            let m = (s + d) % n;
            if mask[m] {
                continue;
            }
            let hi = (s + accel) % n;
            if !mask[hi] {
                continue;
            }
            for x in 0..n {
                let blk = block([s, hi], x);
                for c in 0..coils {
                    let mut acc = (0.0, 0.0);
                    for j in 0..dim {
                        acc = cadd(acc, cmul(blk[j], rhs[j * coils + c]));
                    }
                    let i = c * plane + m * n + x;
                    out_re[i] = acc.0 as f32;
                    out_im[i] = acc.1 as f32;
                }
            }
        }
    }
    Ok((out_re, out_im))
}

/// Scalar LZW compress — dictionary keyed by owned byte strings, cloning
/// the current sequence on every input byte (the allocation the optimized
/// path removes; output must stay bit-identical).
pub fn lzw_compress(input: &[u8]) -> Vec<u8> {
    use super::lzw::{width_for, BitWriter, DICT_LIMIT};
    if input.is_empty() {
        return Vec::new();
    }
    let mut dict: HashMap<Vec<u8>, u32> = (0..256u32).map(|b| (vec![b as u8], b)).collect();
    let mut next_code = 256u32;
    let mut writer = BitWriter::new();
    let mut current = vec![input[0]];
    for &b in &input[1..] {
        let mut candidate = current.clone();
        candidate.push(b);
        if dict.contains_key(&candidate) {
            current = candidate;
        } else {
            let code = dict[&current];
            writer.push(code, width_for(next_code as usize));
            if (next_code as usize) < DICT_LIMIT {
                dict.insert(candidate, next_code);
                next_code += 1;
            }
            current = vec![b];
        }
    }
    let code = dict[&current];
    writer.push(code, width_for(next_code as usize));
    writer.finish()
}
