//! Histogram equalization (Table I workload).
//!
//! Classic 256-bin global equalization over the `[0,1]` float image, using
//! the standard CDF remap `v' = (cdf(v) - cdf_min) / (N - cdf_min)`.

use super::image::Image;
use crate::util::parallel::{par_chunks_mut, par_fold};

/// Number of histogram bins (8-bit intensity resolution).
pub const BINS: usize = 256;

#[inline]
fn bin_of(v: f32) -> usize {
    ((v.clamp(0.0, 1.0) * 255.0).round() as usize).min(BINS - 1)
}

/// Compute the 256-bin histogram of an image. Counted per band in parallel
/// and merged; integer adds commute, so the result is exact regardless of
/// thread count.
pub fn histogram(img: &Image) -> [u32; BINS] {
    let data = &img.data;
    const BAND: usize = 32 * 1024;
    let n_bands = data.len().div_ceil(BAND);
    par_fold(
        n_bands,
        2,
        |band| {
            let mut h = [0u32; BINS];
            let lo = band.start * BAND;
            let hi = (band.end * BAND).min(data.len());
            for &v in &data[lo..hi] {
                h[bin_of(v)] += 1;
            }
            h
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += y;
            }
            a
        },
    )
    .unwrap_or([0u32; BINS])
}

/// Globally equalize the histogram.
pub fn equalize(img: &Image) -> Image {
    let hist = histogram(img);
    let n = img.data.len() as u64;
    // CDF and its first non-zero value.
    let mut cdf = [0u64; BINS];
    let mut acc = 0u64;
    for (i, &c) in hist.iter().enumerate() {
        acc += c as u64;
        cdf[i] = acc;
    }
    let cdf_min = cdf.iter().copied().find(|&c| c > 0).unwrap_or(0);
    let denom = (n - cdf_min).max(1) as f32;

    let mut lut = [0f32; BINS];
    for i in 0..BINS {
        lut[i] = ((cdf[i].saturating_sub(cdf_min)) as f32 / denom).clamp(0.0, 1.0);
    }
    // Write into a fresh buffer — the source is only read through the LUT,
    // so cloning it first (as the original did) was a wasted full-image copy.
    let mut out = Image::zeros(img.width, img.height);
    let src = &img.data;
    const CHUNK: usize = 4096;
    par_chunks_mut(&mut out.data, CHUNK, |i, chunk| {
        let base = i * CHUNK;
        for (o, &v) in chunk.iter_mut().zip(&src[base..base + chunk.len()]) {
            *o = lut[bin_of(v)];
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn histogram_counts_all_pixels() {
        let img = Image::from_data(2, 2, vec![0.0, 0.5, 0.5, 1.0]).unwrap();
        let h = histogram(&img);
        assert_eq!(h.iter().map(|&c| c as usize).sum::<usize>(), 4);
        assert_eq!(h[0], 1);
        assert_eq!(h[128], 2);
        assert_eq!(h[255], 1);
    }

    #[test]
    fn equalization_stretches_low_contrast() {
        // Narrow band [0.4, 0.6] should spread towards [0, 1].
        let mut rng = Rng::new(5);
        let mut img = Image::zeros(64, 64);
        for v in &mut img.data {
            *v = 0.4 + 0.2 * rng.next_f32();
        }
        let eq = equalize(&img);
        let (mn0, mx0) = img.min_max();
        let (mn1, mx1) = eq.min_max();
        assert!(mx1 - mn1 > (mx0 - mn0) * 2.0, "contrast should stretch");
        assert!(mx1 > 0.95);
    }

    #[test]
    fn equalization_is_monotone() {
        let mut rng = Rng::new(6);
        let mut img = Image::zeros(32, 32);
        for v in &mut img.data {
            *v = rng.next_f32();
        }
        let eq = equalize(&img);
        // pixel order (by intensity) must be preserved
        let mut pairs: Vec<(f32, f32)> = img.data.iter().copied().zip(eq.data.iter().copied()).collect();
        pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in pairs.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-6, "equalization must be monotone");
        }
    }

    #[test]
    fn constant_image_maps_to_zero() {
        let mut img = Image::zeros(8, 8);
        for v in &mut img.data {
            *v = 0.7;
        }
        let eq = equalize(&img);
        for &v in &eq.data {
            assert_eq!(v, 0.0);
        }
    }
}
