//! GRAPPA parallel-imaging reconstruction kernel.
//!
//! Fits one complex weight set per row offset `d ∈ 1..R` by least squares
//! over the auto-calibration rows: every sampled target row whose two
//! bracketing source rows (`t-d` and `t-d+R`, wrapped) are also sampled
//! contributes `n` equations relating a 2-row × 3-column × all-coil
//! source block to the target sample in each coil. The normal equations
//! accumulate in f64 with a relative Tikhonov ridge and are solved by
//! complex Gauss–Jordan elimination with partial pivoting; the fitted
//! weights then synthesize every missing row from its nearest sampled
//! neighbours. Accumulation band-splits over calibration rows through
//! [`crate::util::parallel::par_fold`] (band partials fold in band order,
//! so any fixed thread count is deterministic; `EDGEPIPE_THREADS=1`
//! reproduces the serial oracle in
//! [`crate::imaging::reference::grappa_recon`] exactly).

// Per-frame recon path: a panic here kills the source thread.
#![deny(clippy::unwrap_used)]

use crate::error::{Error, Result};
use crate::util::parallel::par_fold;

/// Source taps per target sample: 2 rows × 3 columns (× all coils).
pub const TAPS: usize = 6;

/// Fitted GRAPPA interpolation weights for one `(coils, accel)` geometry.
///
/// [`Self::fit`] autocalibrates against one acquired k-space (it may
/// allocate — the normal-equation scratch is per-band); [`Self::apply`]
/// is the per-frame synthesis entry point.
#[derive(Debug, Clone)]
pub struct GrappaKernel {
    coils: usize,
    accel: usize,
    /// Source-block size: [`TAPS`]` * coils`.
    dim: usize,
    /// Per offset `d ∈ 1..accel`: `dim × coils` complex weights,
    /// interleaved `[re, im]`.
    weights: Vec<f64>,
    /// Calibration target rows (scratch reused across fits).
    rows: Vec<usize>,
    fitted: bool,
}

impl GrappaKernel {
    /// A kernel for `coils` receive channels at acceleration `accel`.
    pub fn new(coils: usize, accel: usize) -> Result<GrappaKernel> {
        if coils == 0 || accel == 0 {
            return Err(Error::Imaging(format!(
                "grappa kernel needs coils >= 1 and accel >= 1 (got {coils}, {accel})"
            )));
        }
        let dim = TAPS * coils;
        Ok(GrappaKernel {
            coils,
            accel,
            dim,
            weights: vec![0.0; accel.saturating_sub(1) * dim * coils * 2],
            rows: Vec::new(),
            fitted: false,
        })
    }

    /// Receive-channel count.
    pub fn coils(&self) -> usize {
        self.coils
    }

    /// Acceleration factor R.
    pub fn accel(&self) -> usize {
        self.accel
    }

    fn check_planes(&self, n: usize, re: &[f32], im: &[f32]) -> Result<()> {
        let want = self.coils * n * n;
        if n == 0 || re.len() != want || im.len() != want {
            return Err(Error::Imaging(format!(
                "grappa plane lengths {}/{} != coils {} x {n}x{n}",
                re.len(),
                im.len(),
                self.coils
            )));
        }
        Ok(())
    }

    fn check_fitted(&self) -> Result<()> {
        if self.fitted {
            Ok(())
        } else {
            Err(Error::Imaging("grappa apply before fit".into()))
        }
    }

    /// Autocalibrate the per-offset weights from the sampled rows of one
    /// acquired multi-coil k-space (`coils` planes of `n*n`, coil-major;
    /// `mask[row]` marks sampled rows). `lambda_rel` is the Tikhonov
    /// ridge relative to the mean Gram diagonal.
    pub fn fit(
        &mut self,
        ks_re: &[f32],
        ks_im: &[f32],
        mask: &[bool],
        lambda_rel: f64,
    ) -> Result<()> {
        let n = mask.len();
        self.check_planes(n, ks_re, ks_im)?;
        if self.accel < 2 {
            // Fully sampled: nothing to synthesize, nothing to fit.
            self.fitted = true;
            return Ok(());
        }
        let (dim, coils, accel) = (self.dim, self.coils, self.accel);
        let plane = n * n;
        for d in 1..accel {
            self.rows.clear();
            for t in 0..n {
                let lo = (t + n - d) % n;
                let hi = (lo + accel) % n;
                if mask[t] && mask[lo] && mask[hi] {
                    self.rows.push(t);
                }
            }
            if self.rows.is_empty() {
                return Err(Error::Imaging(format!(
                    "grappa fit: no calibration rows for offset {d} at R={accel} \
                     (widen the ACS band)"
                )));
            }
            // Banded normal-equation accumulation: Gram (dim×dim) and
            // right-hand side (dim×coils), complex interleaved, in f64.
            let rows = &self.rows;
            let acc = par_fold(
                rows.len(),
                8,
                |band| {
                    let mut g = vec![0.0f64; dim * dim * 2];
                    let mut r = vec![0.0f64; dim * coils * 2];
                    let mut blk = vec![0.0f64; dim * 2];
                    for &t in &rows[band] {
                        let lo = (t + n - d) % n;
                        let hi = (lo + accel) % n;
                        for x in 0..n {
                            gather_block(ks_re, ks_im, n, coils, [lo, hi], x, &mut blk);
                            for j in 0..dim {
                                let (ar, ai) = (blk[j * 2], blk[j * 2 + 1]);
                                // G[j][k] += conj(blk[j]) · blk[k]
                                for k in 0..dim {
                                    let (br, bi) = (blk[k * 2], blk[k * 2 + 1]);
                                    let gi = (j * dim + k) * 2;
                                    g[gi] += ar * br + ai * bi;
                                    g[gi + 1] += ar * bi - ai * br;
                                }
                                // r[j][c] += conj(blk[j]) · tgt[c]
                                for c in 0..coils {
                                    let ti = c * plane + t * n + x;
                                    let (tr, tim) = (ks_re[ti] as f64, ks_im[ti] as f64);
                                    let ri = (j * coils + c) * 2;
                                    r[ri] += ar * tr + ai * tim;
                                    r[ri + 1] += ar * tim - ai * tr;
                                }
                            }
                        }
                    }
                    (g, r)
                },
                |(mut ga, mut ra), (gb, rb)| {
                    for (a, b) in ga.iter_mut().zip(&gb) {
                        *a += b;
                    }
                    for (a, b) in ra.iter_mut().zip(&rb) {
                        *a += b;
                    }
                    (ga, ra)
                },
            );
            let Some((mut gram, mut rhs)) = acc else {
                return Err(Error::Imaging("grappa fit: empty calibration".into()));
            };
            // Relative ridge: λ = lambda_rel · tr(G).re / dim.
            let mut trace = 0.0f64;
            for j in 0..dim {
                trace += gram[(j * dim + j) * 2];
            }
            let lam = lambda_rel * trace / dim as f64;
            for j in 0..dim {
                gram[(j * dim + j) * 2] += lam;
            }
            solve_complex(&mut gram, &mut rhs, dim, coils)?;
            let w0 = (d - 1) * dim * coils * 2;
            self.weights[w0..w0 + dim * coils * 2].copy_from_slice(&rhs);
        }
        self.fitted = true;
        Ok(())
    }

    /// Synthesize every missing row in place from the fitted weights.
    /// Per-frame: validation + delegation only (loops live in
    /// [`apply_offsets`]).
    pub fn apply(&self, ks_re: &mut [f32], ks_im: &mut [f32], mask: &[bool]) -> Result<()> {
        let n = mask.len();
        self.check_planes(n, ks_re, ks_im)?;
        self.check_fitted()?;
        if self.accel < 2 {
            return Ok(());
        }
        apply_offsets(self, ks_re, ks_im, mask);
        Ok(())
    }
}

/// Gather the 2-row × 3-column × all-coil complex source block around
/// column `x` into `blk` (f64 interleaved), in the fit/apply tap order:
/// row-major over `rows`, then `dx ∈ {-1, 0, +1}` (wrapped), then coils.
fn gather_block(
    ks_re: &[f32],
    ks_im: &[f32],
    n: usize,
    coils: usize,
    rows: [usize; 2],
    x: usize,
    blk: &mut [f64],
) {
    let plane = n * n;
    let mut j = 0usize;
    for row in rows {
        for dx in [n - 1, 0, 1] {
            let xc = (x + dx) % n;
            for c in 0..coils {
                let idx = c * plane + row * n + xc;
                blk[j] = ks_re[idx] as f64;
                blk[j + 1] = ks_im[idx] as f64;
                j += 2;
            }
        }
    }
}

/// Fill the missing rows: for every sampled row `s` and offset `d`, the
/// row `s+d` (wrapped) is synthesized from the blocks of `s` and `s+R`
/// when it is unsampled and `s+R` is sampled. Sources are always sampled
/// rows, so in-place filling never reads a synthesized value.
fn apply_offsets(k: &GrappaKernel, ks_re: &mut [f32], ks_im: &mut [f32], mask: &[bool]) {
    let n = mask.len();
    let (coils, dim, accel) = (k.coils, k.dim, k.accel);
    let plane = n * n;
    let mut blk = vec![0.0f64; dim * 2];
    let mut acc = vec![0.0f64; coils * 2];
    for d in 1..accel {
        let w0 = (d - 1) * dim * coils * 2;
        for s in 0..n {
            if !mask[s] {
                continue;
            }
            let m = (s + d) % n;
            if mask[m] {
                continue;
            }
            let hi = (s + accel) % n;
            if !mask[hi] {
                continue;
            }
            for x in 0..n {
                gather_block(ks_re, ks_im, n, coils, [s, hi], x, &mut blk);
                for a in acc.iter_mut() {
                    *a = 0.0;
                }
                for j in 0..dim {
                    let (br, bi) = (blk[j * 2], blk[j * 2 + 1]);
                    for c in 0..coils {
                        let wi = w0 + (j * coils + c) * 2;
                        let (wr, wim) = (k.weights[wi], k.weights[wi + 1]);
                        acc[c * 2] += br * wr - bi * wim;
                        acc[c * 2 + 1] += br * wim + bi * wr;
                    }
                }
                for c in 0..coils {
                    let idx = c * plane + m * n + x;
                    ks_re[idx] = acc[c * 2] as f32;
                    ks_im[idx] = acc[c * 2 + 1] as f32;
                }
            }
        }
    }
}

/// In-place complex Gauss–Jordan with partial pivoting: solves
/// `gram · W = rhs` (`dim×dim` and `dim×coils` complex interleaved),
/// leaving `W` in `rhs`. Errors on a singular calibration system.
fn solve_complex(gram: &mut [f64], rhs: &mut [f64], dim: usize, coils: usize) -> Result<()> {
    for col in 0..dim {
        let mut pivot = col;
        let mut best = 0.0f64;
        for r in col..dim {
            let gi = (r * dim + col) * 2;
            let mag = gram[gi] * gram[gi] + gram[gi + 1] * gram[gi + 1];
            if mag > best {
                best = mag;
                pivot = r;
            }
        }
        if best <= f64::MIN_POSITIVE {
            return Err(Error::Imaging(format!(
                "grappa fit: singular calibration system at column {col}"
            )));
        }
        if pivot != col {
            swap_rows(gram, dim * 2, pivot, col);
            swap_rows(rhs, coils * 2, pivot, col);
        }
        let pi = (col * dim + col) * 2;
        let (pr, pim) = (gram[pi], gram[pi + 1]);
        let inv = 1.0 / (pr * pr + pim * pim);
        let (sr, si) = (pr * inv, -pim * inv);
        scale_row(gram, dim, col, sr, si);
        scale_row(rhs, coils, col, sr, si);
        for r in 0..dim {
            if r == col {
                continue;
            }
            let fi = (r * dim + col) * 2;
            let (fr, fim) = (gram[fi], gram[fi + 1]);
            if fr == 0.0 && fim == 0.0 {
                continue;
            }
            axpy_row(gram, dim, r, col, fr, fim);
            axpy_row(rhs, coils, r, col, fr, fim);
        }
    }
    Ok(())
}

/// Swap flat rows `r0` and `r1` of a matrix with `stride` scalars/row.
fn swap_rows(a: &mut [f64], stride: usize, r0: usize, r1: usize) {
    for k in 0..stride {
        a.swap(r0 * stride + k, r1 * stride + k);
    }
}

/// Complex row scale: row `r` ×= `(sr + i·si)` (`cols` complex entries).
fn scale_row(a: &mut [f64], cols: usize, r: usize, sr: f64, si: f64) {
    for k in 0..cols {
        let i = (r * cols + k) * 2;
        let (xr, xi) = (a[i], a[i + 1]);
        a[i] = xr * sr - xi * si;
        a[i + 1] = xr * si + xi * sr;
    }
}

/// Complex row update: row `r` -= `(fr + i·fi)` × row `src`.
fn axpy_row(a: &mut [f64], cols: usize, r: usize, src: usize, fr: f64, fi: f64) {
    for k in 0..cols {
        let s = (src * cols + k) * 2;
        let (xr, xi) = (a[s], a[s + 1]);
        let di = (r * cols + k) * 2;
        a[di] -= xr * fr - xi * fi;
        a[di + 1] -= xr * fi + xi * fr;
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn rejects_degenerate_geometry_and_unfitted_apply() {
        assert!(GrappaKernel::new(0, 2).is_err());
        assert!(GrappaKernel::new(4, 0).is_err());
        let k = GrappaKernel::new(2, 2).unwrap();
        let mut re = vec![0.0f32; 2 * 16];
        let mut im = vec![0.0f32; 2 * 16];
        let mask = vec![true; 4];
        assert!(k.apply(&mut re, &mut im, &mask).is_err(), "apply before fit");
    }

    #[test]
    fn all_zero_calibration_is_reported_singular() {
        let n = 8usize;
        let mut k = GrappaKernel::new(2, 2).unwrap();
        let re = vec![0.0f32; 2 * n * n];
        let im = vec![0.0f32; 2 * n * n];
        let mask = vec![true; n];
        assert!(k.fit(&re, &im, &mask, 1e-4).is_err());
    }

    #[test]
    fn solve_recovers_a_known_complex_system() {
        // gram = diag(2, 1+i); rhs column = (4, 2) → W = (2, (2)·(1+i)⁻¹)
        let dim = 2;
        let mut gram = vec![0.0f64; dim * dim * 2];
        gram[0] = 2.0; // (0,0) = 2
        gram[(dim + 1) * 2] = 1.0; // (1,1) = 1+i
        gram[(dim + 1) * 2 + 1] = 1.0;
        let mut rhs = vec![4.0, 0.0, 2.0, 0.0];
        solve_complex(&mut gram, &mut rhs, dim, 1).unwrap();
        assert!((rhs[0] - 2.0).abs() < 1e-12 && rhs[1].abs() < 1e-12);
        assert!((rhs[2] - 1.0).abs() < 1e-12 && (rhs[3] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn r1_fit_and_apply_are_identity() {
        let n = 8usize;
        let mut k = GrappaKernel::new(1, 1).unwrap();
        let src: Vec<f32> = (0..n * n).map(|i| i as f32 * 0.01).collect();
        let mut re = src.clone();
        let mut im = vec![0.0f32; n * n];
        let mask = vec![true; n];
        k.fit(&re, &im, &mask, 1e-4).unwrap();
        k.apply(&mut re, &mut im, &mask).unwrap();
        assert_eq!(re, src);
    }
}
