//! Canny edge detector (Table I workload).
//!
//! Full classical pipeline: Gaussian smoothing → Sobel gradients →
//! non-maximum suppression → double threshold → hysteresis by BFS.
//!
//! The smoothing, gradient, NMS, and threshold stages are row-independent
//! and run in parallel under the `parallel` feature, each with the clamped
//! border split out of the flat interior loop. Only the hysteresis BFS
//! (a global flood fill) stays serial; its result is a reachable set and
//! therefore independent of seed order, so the whole detector is
//! bit-identical to the scalar reference
//! ([`crate::imaging::reference::canny`]).

use super::image::Image;
use super::sobel::sobel;
use crate::util::parallel::par_chunks_mut;

/// 5×5 Gaussian blur (sigma ≈ 1.0), separable implementation.
pub fn gaussian5(img: &Image) -> Image {
    const K: [f32; 5] = [1.0, 4.0, 6.0, 4.0, 1.0]; // binomial, sum 16
    let (w, h) = (img.width, img.height);
    let mut tmp = Image::zeros(w, h);
    if w == 0 || h == 0 {
        return tmp;
    }
    // Horizontal pass: clamped only within 2 columns of the sides.
    let src = &img.data;
    par_chunks_mut(&mut tmp.data, w, |y, row| {
        let cur = &src[y * w..(y + 1) * w];
        let border = 2.min(w);
        for x in 0..border {
            row[x] = h5_clamped(cur, x);
        }
        for x in 2..w.saturating_sub(2) {
            row[x] =
                (cur[x - 2] + 4.0 * cur[x - 1] + 6.0 * cur[x] + 4.0 * cur[x + 1] + cur[x + 2])
                    / 16.0;
        }
        for x in w.saturating_sub(2).max(border)..w {
            row[x] = h5_clamped(cur, x);
        }
    });
    // Vertical pass: rows 2..h-2 read five whole rows; edge rows clamp.
    let mut out = Image::zeros(w, h);
    let smoothed = &tmp;
    let src = &tmp.data;
    par_chunks_mut(&mut out.data, w, |y, row| {
        if y < 2 || y + 2 >= h {
            for (x, o) in row.iter_mut().enumerate() {
                let mut s = 0.0;
                for (i, &k) in K.iter().enumerate() {
                    s += k * smoothed.get_clamped(x as isize, y as isize + i as isize - 2);
                }
                *o = s / 16.0;
            }
            return;
        }
        let r0 = &src[(y - 2) * w..(y - 1) * w];
        let r1 = &src[(y - 1) * w..y * w];
        let r2 = &src[y * w..(y + 1) * w];
        let r3 = &src[(y + 1) * w..(y + 2) * w];
        let r4 = &src[(y + 2) * w..(y + 3) * w];
        for (x, o) in row.iter_mut().enumerate() {
            *o = (r0[x] + 4.0 * r1[x] + 6.0 * r2[x] + 4.0 * r3[x] + r4[x]) / 16.0;
        }
    });
    out
}

/// Horizontal 5-tap with replicate clamping, summed in kernel order so the
/// border matches the reference bit-for-bit.
#[inline]
fn h5_clamped(row: &[f32], x: usize) -> f32 {
    const K: [f32; 5] = [1.0, 4.0, 6.0, 4.0, 1.0];
    let n = row.len() as isize;
    let mut s = 0.0;
    for (i, &k) in K.iter().enumerate() {
        let xx = (x as isize + i as isize - 2).clamp(0, n - 1) as usize;
        s += k * row[xx];
    }
    s / 16.0
}

/// Canny edges: binary image with 1.0 at edge pixels.
pub fn canny(img: &Image, low: f32, high: f32) -> Image {
    assert!(low <= high, "low threshold must be <= high");
    let smoothed = gaussian5(img);
    let g = sobel(&smoothed);
    let (w, h) = (img.width, img.height);

    // Non-maximum suppression along the quantized gradient direction.
    let mut nms = Image::zeros(w, h);
    if w > 0 && h > 0 {
        let mag = &g.magnitude;
        let mag_data = &g.magnitude.data;
        let dir = &g.direction;
        par_chunks_mut(&mut nms.data, w, |y, row| {
            let interior_y = y > 0 && y + 1 < h;
            for (x, o) in row.iter_mut().enumerate() {
                let m = mag_data[y * w + x];
                if m == 0.0 {
                    continue;
                }
                let angle = dir[y * w + x];
                // Quantize direction to 0/45/90/135 degrees.
                let deg = angle.to_degrees();
                let deg = if deg < 0.0 { deg + 180.0 } else { deg };
                let (dx, dy): (isize, isize) = if !(22.5..157.5).contains(&deg) {
                    (1, 0)
                } else if deg < 67.5 {
                    (1, 1)
                } else if deg < 112.5 {
                    (0, 1)
                } else {
                    (-1, 1)
                };
                let (a, b) = if interior_y && x > 0 && x + 1 < w {
                    let fwd = (y as isize + dy) as usize * w + (x as isize + dx) as usize;
                    let back = (y as isize - dy) as usize * w + (x as isize - dx) as usize;
                    (mag_data[fwd], mag_data[back])
                } else {
                    (
                        mag.get_clamped(x as isize + dx, y as isize + dy),
                        mag.get_clamped(x as isize - dx, y as isize - dy),
                    )
                };
                if m >= a && m >= b {
                    *o = m;
                }
            }
        });
    }

    // Double threshold + hysteresis. Marks are written row-parallel; seeds
    // are collected serially afterwards (the BFS reachable set does not
    // depend on seed order).
    const WEAK: f32 = 0.5;
    const STRONG: f32 = 1.0;
    let mut marks = Image::zeros(w, h);
    let nms_data = &nms.data;
    par_chunks_mut(&mut marks.data, w, |y, row| {
        for (x, o) in row.iter_mut().enumerate() {
            let m = nms_data[y * w + x];
            if m >= high {
                *o = STRONG;
            } else if m >= low {
                *o = WEAK;
            }
        }
    });
    let mut stack: Vec<(usize, usize)> = Vec::new();
    for y in 0..h {
        for x in 0..w {
            if marks.get(x, y) == STRONG {
                stack.push((x, y));
            }
        }
    }
    // BFS from strong pixels through weak neighbours.
    while let Some((x, y)) = stack.pop() {
        for dy in -1isize..=1 {
            for dx in -1isize..=1 {
                let nx = x as isize + dx;
                let ny = y as isize + dy;
                if nx < 0 || ny < 0 || nx >= w as isize || ny >= h as isize {
                    continue;
                }
                let (nx, ny) = (nx as usize, ny as usize);
                if marks.get(nx, ny) == WEAK {
                    marks.set(nx, ny, STRONG);
                    stack.push((nx, ny));
                }
            }
        }
    }
    for v in &mut marks.data {
        *v = if *v == STRONG { 1.0 } else { 0.0 };
    }
    marks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn disc_image(n: usize, r: f32) -> Image {
        let mut img = Image::zeros(n, n);
        let c = n as f32 / 2.0;
        for y in 0..n {
            for x in 0..n {
                let d = ((x as f32 - c).powi(2) + (y as f32 - c).powi(2)).sqrt();
                if d < r {
                    img.set(x, y, 1.0);
                }
            }
        }
        img
    }

    #[test]
    fn finds_disc_boundary() {
        let img = disc_image(32, 10.0);
        let edges = canny(&img, 0.1, 0.3);
        let edge_count = edges.data.iter().filter(|&&v| v == 1.0).count();
        // circumference ~ 2*pi*10 ~ 63 pixels; allow slack for discretization
        assert!(
            (30..200).contains(&edge_count),
            "edge pixel count {edge_count}"
        );
        // no edges well inside or outside the disc
        assert_eq!(edges.get(16, 16), 0.0);
        assert_eq!(edges.get(1, 1), 0.0);
    }

    #[test]
    fn constant_image_yields_nothing() {
        let mut img = Image::zeros(16, 16);
        for v in &mut img.data {
            *v = 0.4;
        }
        let edges = canny(&img, 0.05, 0.15);
        assert!(edges.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hysteresis_connects_weak_to_strong() {
        // A faint-but-connected edge should survive via hysteresis: use a
        // step whose magnitude sits between low and high except one strong
        // seed point.
        let img = disc_image(32, 10.0);
        let strict = canny(&img, 0.28, 0.29);
        let lenient = canny(&img, 0.05, 0.29);
        let n_strict = strict.data.iter().filter(|&&v| v == 1.0).count();
        let n_lenient = lenient.data.iter().filter(|&&v| v == 1.0).count();
        assert!(n_lenient >= n_strict);
    }

    #[test]
    #[should_panic(expected = "low threshold")]
    fn bad_thresholds_panic() {
        canny(&Image::zeros(8, 8), 0.5, 0.1);
    }

    #[test]
    fn gaussian_preserves_mean() {
        let img = disc_image(32, 8.0);
        let blurred = gaussian5(&img);
        assert!((img.mean() - blurred.mean()).abs() < 0.02);
    }
}
