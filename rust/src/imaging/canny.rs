//! Canny edge detector (Table I workload).
//!
//! Full classical pipeline: Gaussian smoothing → Sobel gradients →
//! non-maximum suppression → double threshold → hysteresis by BFS.

use super::image::Image;
use super::sobel::sobel;

/// 5×5 Gaussian blur (sigma ≈ 1.0), separable implementation.
pub fn gaussian5(img: &Image) -> Image {
    const K: [f32; 5] = [1.0, 4.0, 6.0, 4.0, 1.0]; // binomial, sum 16
    let (w, h) = (img.width, img.height);
    let mut tmp = Image::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let mut s = 0.0;
            for (i, &k) in K.iter().enumerate() {
                s += k * img.get_clamped(x as isize + i as isize - 2, y as isize);
            }
            tmp.set(x, y, s / 16.0);
        }
    }
    let mut out = Image::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let mut s = 0.0;
            for (i, &k) in K.iter().enumerate() {
                s += k * tmp.get_clamped(x as isize, y as isize + i as isize - 2);
            }
            out.set(x, y, s / 16.0);
        }
    }
    out
}

/// Canny edges: binary image with 1.0 at edge pixels.
pub fn canny(img: &Image, low: f32, high: f32) -> Image {
    assert!(low <= high, "low threshold must be <= high");
    let smoothed = gaussian5(img);
    let g = sobel(&smoothed);
    let (w, h) = (img.width, img.height);

    // Non-maximum suppression along the quantized gradient direction.
    let mut nms = Image::zeros(w, h);
    for y in 0..h {
        for x in 0..w {
            let m = g.magnitude.get(x, y);
            if m == 0.0 {
                continue;
            }
            let angle = g.direction[y * w + x];
            // Quantize direction to 0/45/90/135 degrees.
            let deg = angle.to_degrees();
            let deg = if deg < 0.0 { deg + 180.0 } else { deg };
            let (dx, dy): (isize, isize) = if !(22.5..157.5).contains(&deg) {
                (1, 0)
            } else if deg < 67.5 {
                (1, 1)
            } else if deg < 112.5 {
                (0, 1)
            } else {
                (-1, 1)
            };
            let a = g.magnitude.get_clamped(x as isize + dx, y as isize + dy);
            let b = g.magnitude.get_clamped(x as isize - dx, y as isize - dy);
            if m >= a && m >= b {
                nms.set(x, y, m);
            }
        }
    }

    // Double threshold + hysteresis.
    const WEAK: f32 = 0.5;
    const STRONG: f32 = 1.0;
    let mut marks = Image::zeros(w, h);
    let mut stack = Vec::new();
    for y in 0..h {
        for x in 0..w {
            let m = nms.get(x, y);
            if m >= high {
                marks.set(x, y, STRONG);
                stack.push((x, y));
            } else if m >= low {
                marks.set(x, y, WEAK);
            }
        }
    }
    // BFS from strong pixels through weak neighbours.
    while let Some((x, y)) = stack.pop() {
        for dy in -1isize..=1 {
            for dx in -1isize..=1 {
                let nx = x as isize + dx;
                let ny = y as isize + dy;
                if nx < 0 || ny < 0 || nx >= w as isize || ny >= h as isize {
                    continue;
                }
                let (nx, ny) = (nx as usize, ny as usize);
                if marks.get(nx, ny) == WEAK {
                    marks.set(nx, ny, STRONG);
                    stack.push((nx, ny));
                }
            }
        }
    }
    for v in &mut marks.data {
        *v = if *v == STRONG { 1.0 } else { 0.0 };
    }
    marks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn disc_image(n: usize, r: f32) -> Image {
        let mut img = Image::zeros(n, n);
        let c = n as f32 / 2.0;
        for y in 0..n {
            for x in 0..n {
                let d = ((x as f32 - c).powi(2) + (y as f32 - c).powi(2)).sqrt();
                if d < r {
                    img.set(x, y, 1.0);
                }
            }
        }
        img
    }

    #[test]
    fn finds_disc_boundary() {
        let img = disc_image(32, 10.0);
        let edges = canny(&img, 0.1, 0.3);
        let edge_count = edges.data.iter().filter(|&&v| v == 1.0).count();
        // circumference ~ 2*pi*10 ~ 63 pixels; allow slack for discretization
        assert!(
            (30..200).contains(&edge_count),
            "edge pixel count {edge_count}"
        );
        // no edges well inside or outside the disc
        assert_eq!(edges.get(16, 16), 0.0);
        assert_eq!(edges.get(1, 1), 0.0);
    }

    #[test]
    fn constant_image_yields_nothing() {
        let mut img = Image::zeros(16, 16);
        for v in &mut img.data {
            *v = 0.4;
        }
        let edges = canny(&img, 0.05, 0.15);
        assert!(edges.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn hysteresis_connects_weak_to_strong() {
        // A faint-but-connected edge should survive via hysteresis: use a
        // step whose magnitude sits between low and high except one strong
        // seed point.
        let img = disc_image(32, 10.0);
        let strict = canny(&img, 0.28, 0.29);
        let lenient = canny(&img, 0.05, 0.29);
        let n_strict = strict.data.iter().filter(|&&v| v == 1.0).count();
        let n_lenient = lenient.data.iter().filter(|&&v| v == 1.0).count();
        assert!(n_lenient >= n_strict);
    }

    #[test]
    #[should_panic(expected = "low threshold")]
    fn bad_thresholds_panic() {
        canny(&Image::zeros(8, 8), 0.5, 0.1);
    }

    #[test]
    fn gaussian_preserves_mean() {
        let img = disc_image(32, 8.0);
        let blurred = gaussian5(&img);
        assert!((img.mean() - blurred.mean()).abs() < 0.02);
    }
}
