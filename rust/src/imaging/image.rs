//! Grayscale image container and PGM I/O.
//!
//! All medical images in the pipeline are single-channel `f32` in `[0, 1]`,
//! stored row-major. PGM (P5, 8-bit) is the interchange format for sample
//! outputs (Fig 7) because it needs no external codec.

use crate::error::{Error, Result};
use std::io::Write as _;
use std::path::Path;

/// A row-major single-channel `f32` image.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub data: Vec<f32>,
}

impl Image {
    /// Create a zero-filled image.
    pub fn zeros(width: usize, height: usize) -> Self {
        Image {
            width,
            height,
            data: vec![0.0; width * height],
        }
    }

    /// Create from raw data (must have `width * height` elements).
    pub fn from_data(width: usize, height: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != width * height {
            return Err(Error::Imaging(format!(
                "data length {} != {}x{}",
                data.len(),
                width,
                height
            )));
        }
        Ok(Image {
            width,
            height,
            data,
        })
    }

    /// Build from a borrowed slice through a per-pixel map: one
    /// allocation, length-validated before mapping (the pipeline's
    /// fidelity path rescales shared `[-1, 1]` planes with this per
    /// scored frame).
    pub fn from_mapped(
        width: usize,
        height: usize,
        src: &[f32],
        f: impl Fn(f32) -> f32,
    ) -> Result<Self> {
        if src.len() != width * height {
            return Err(Error::Imaging(format!(
                "data length {} != {}x{}",
                src.len(),
                width,
                height
            )));
        }
        Ok(Image {
            width,
            height,
            data: src.iter().map(|&v| f(v)).collect(),
        })
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> f32 {
        self.data[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: f32) {
        self.data[y * self.width + x] = v;
    }

    /// Clamped accessor: out-of-range coordinates are clamped to the border
    /// (replicate padding), the convention used by all the filters here.
    #[inline]
    pub fn get_clamped(&self, x: isize, y: isize) -> f32 {
        let xc = x.clamp(0, self.width as isize - 1) as usize;
        let yc = y.clamp(0, self.height as isize - 1) as usize;
        self.get(xc, yc)
    }

    /// Clamp all pixels into `[0, 1]`.
    pub fn clamp01(&mut self) {
        for v in &mut self.data {
            *v = v.clamp(0.0, 1.0);
        }
    }

    /// Min and max pixel values.
    pub fn min_max(&self) -> (f32, f32) {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &v in &self.data {
            mn = mn.min(v);
            mx = mx.max(v);
        }
        (mn, mx)
    }

    /// Mean pixel value.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Quantize to 8-bit, clamping to `[0,1]` first.
    pub fn to_u8(&self) -> Vec<u8> {
        self.data
            .iter()
            .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
            .collect()
    }

    /// Build from 8-bit pixels.
    pub fn from_u8(width: usize, height: usize, bytes: &[u8]) -> Result<Self> {
        if bytes.len() != width * height {
            return Err(Error::Imaging("byte length mismatch".into()));
        }
        Ok(Image {
            width,
            height,
            data: bytes.iter().map(|&b| b as f32 / 255.0).collect(),
        })
    }

    /// Write as binary PGM (P5).
    pub fn save_pgm(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        write!(f, "P5\n{} {}\n255\n", self.width, self.height)?;
        f.write_all(&self.to_u8())?;
        Ok(())
    }

    /// Read a binary PGM (P5).
    pub fn load_pgm(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)?;
        let mut fields: Vec<usize> = Vec::new();
        // Header: magic, width, height, maxval — whitespace separated with
        // optional `#` comments.
        let magic_end = bytes
            .iter()
            .position(|&b| b.is_ascii_whitespace())
            .ok_or_else(|| Error::Imaging("truncated pgm".into()))?;
        if &bytes[..magic_end] != b"P5" {
            return Err(Error::Imaging("not a P5 pgm".into()));
        }
        let mut pos = magic_end;
        while fields.len() < 3 {
            while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if pos < bytes.len() && bytes[pos] == b'#' {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
                continue;
            }
            let start = pos;
            while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            let text = std::str::from_utf8(&bytes[start..pos])
                .map_err(|_| Error::Imaging("bad pgm header".into()))?;
            fields.push(
                text.parse()
                    .map_err(|_| Error::Imaging("bad pgm header number".into()))?,
            );
        }
        pos += 1; // single whitespace after maxval
        let (w, h, maxval) = (fields[0], fields[1], fields[2]);
        if maxval != 255 {
            return Err(Error::Imaging("only 8-bit pgm supported".into()));
        }
        if bytes.len() < pos + w * h {
            return Err(Error::Imaging("truncated pgm data".into()));
        }
        Image::from_u8(w, h, &bytes[pos..pos + w * h])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let mut img = Image::zeros(4, 3);
        assert_eq!(img.data.len(), 12);
        img.set(2, 1, 0.5);
        assert_eq!(img.get(2, 1), 0.5);
        assert_eq!(img.get_clamped(-5, 100), 0.0);
        assert_eq!(img.get_clamped(2, 1), 0.5);
    }

    #[test]
    fn from_data_validates_length() {
        assert!(Image::from_data(2, 2, vec![0.0; 3]).is_err());
        assert!(Image::from_data(2, 2, vec![0.0; 4]).is_ok());
    }

    #[test]
    fn from_mapped_applies_transform() {
        let img = Image::from_mapped(2, 2, &[-1.0, 0.0, 0.5, 1.0], |x| (x + 1.0) / 2.0).unwrap();
        assert_eq!(img.data, vec![0.0, 0.5, 0.75, 1.0]);
        assert!(Image::from_mapped(2, 2, &[0.0; 3], |x| x).is_err());
    }

    #[test]
    fn u8_roundtrip() {
        let img = Image::from_data(2, 2, vec![0.0, 0.25, 0.5, 1.0]).unwrap();
        let b = img.to_u8();
        assert_eq!(b, vec![0, 64, 128, 255]);
        let back = Image::from_u8(2, 2, &b).unwrap();
        for (a, b) in img.data.iter().zip(back.data.iter()) {
            assert!((a - b).abs() < 1.0 / 255.0);
        }
    }

    #[test]
    fn pgm_roundtrip() {
        let dir = std::env::temp_dir().join("edgepipe_pgm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pgm");
        let img = Image::from_data(3, 2, vec![0.0, 0.2, 0.4, 0.6, 0.8, 1.0]).unwrap();
        img.save_pgm(&path).unwrap();
        let back = Image::load_pgm(&path).unwrap();
        assert_eq!(back.width, 3);
        assert_eq!(back.height, 2);
        for (a, b) in img.data.iter().zip(back.data.iter()) {
            assert!((a - b).abs() < 1.0 / 255.0);
        }
    }

    #[test]
    fn min_max_mean() {
        let img = Image::from_data(2, 2, vec![0.1, 0.2, 0.3, 0.4]).unwrap();
        assert_eq!(img.min_max(), (0.1, 0.4));
        assert!((img.mean() - 0.25).abs() < 1e-6);
    }
}
