//! Procedural paired CT/MRI brain phantoms.
//!
//! Substitutes for the paper's private paired CT↔MRI dataset [28] and the
//! Roboflow stroke dataset [35]. A phantom is built from a skull ring, a
//! brain-tissue ellipse, ventricles and optional stroke lesions; the "MRI"
//! counterpart is a *deterministic tissue-contrast remap* of the CT (bone
//! dark, soft-tissue contrast stretched, mild smoothing) so the CT→MRI
//! translation is learnable and the reconstruction accuracy comparison
//! (Table II) is well-posed and reproducible. The Python training data
//! generator (`python/compile/data.py`) mirrors this construction; the two
//! implementations are kept numerically close so rust-side PSNR/SSIM of a
//! python-trained model is meaningful.

use super::image::Image;
use crate::util::rng::Rng;

/// An axis-aligned ground-truth lesion box (for the YOLO detection task).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LesionBox {
    pub cx: f32,
    pub cy: f32,
    pub w: f32,
    pub h: f32,
}

/// A paired sample: CT slice, ground-truth MRI slice, lesion boxes.
#[derive(Debug, Clone)]
pub struct PairedSample {
    pub ct: Image,
    pub mri: Image,
    pub lesions: Vec<LesionBox>,
}

/// Phantom generator parameters.
#[derive(Debug, Clone)]
pub struct PhantomConfig {
    pub size: usize,
    /// Probability that a slice contains 1–2 stroke lesions.
    pub lesion_prob: f64,
    /// CT detector noise sigma (additive Gaussian, before clamping).
    pub noise_sigma: f32,
}

impl Default for PhantomConfig {
    fn default() -> Self {
        PhantomConfig {
            size: 64,
            lesion_prob: 0.7,
            noise_sigma: 0.01,
        }
    }
}

/// Intensity conventions (normalized 0–1, loosely following CT Hounsfield
/// ordering: air < tissue < bone).
const CT_AIR: f32 = 0.05;
const CT_TISSUE: f32 = 0.45;
const CT_VENTRICLE: f32 = 0.30;
const CT_BONE: f32 = 0.95;
const CT_LESION: f32 = 0.38;

/// Generate one paired CT/MRI sample.
pub fn paired_sample(cfg: &PhantomConfig, rng: &mut Rng) -> PairedSample {
    let n = cfg.size;
    let mut labels = vec![0u8; n * n]; // 0 air, 1 tissue, 2 ventricle, 3 bone, 4 lesion
    let c = n as f32 / 2.0;
    // Randomized head geometry.
    let rx = rng.range_f64(0.36, 0.44) as f32 * n as f32;
    let ry = rng.range_f64(0.40, 0.47) as f32 * n as f32;
    let skull_t = rng.range_f64(0.04, 0.07) as f32 * n as f32;
    let tilt = rng.range_f64(-0.2, 0.2) as f32;

    let (sin_t, cos_t) = (tilt.sin(), tilt.cos());
    let inside = |x: f32, y: f32, rx: f32, ry: f32| -> bool {
        let dx = x - c;
        let dy = y - c;
        let u = cos_t * dx + sin_t * dy;
        let v = -sin_t * dx + cos_t * dy;
        (u / rx) * (u / rx) + (v / ry) * (v / ry) <= 1.0
    };

    for y in 0..n {
        for x in 0..n {
            let (xf, yf) = (x as f32, y as f32);
            let idx = y * n + x;
            if inside(xf, yf, rx - skull_t, ry - skull_t) {
                labels[idx] = 1;
            } else if inside(xf, yf, rx, ry) {
                labels[idx] = 3;
            }
        }
    }

    // Ventricles: two small ellipses near centre.
    for side in [-1.0f32, 1.0f32] {
        let vx = c + side * rng.range_f64(0.08, 0.14) as f32 * n as f32;
        let vy = c + rng.range_f64(-0.05, 0.05) as f32 * n as f32;
        let vrx = rng.range_f64(0.04, 0.07) as f32 * n as f32;
        let vry = rng.range_f64(0.08, 0.13) as f32 * n as f32;
        for y in 0..n {
            for x in 0..n {
                let dx = (x as f32 - vx) / vrx;
                let dy = (y as f32 - vy) / vry;
                if dx * dx + dy * dy <= 1.0 && labels[y * n + x] == 1 {
                    labels[y * n + x] = 2;
                }
            }
        }
    }

    // Stroke lesions.
    let mut lesions = Vec::new();
    if rng.chance(cfg.lesion_prob) {
        let count = 1 + rng.below(2) as usize;
        for _ in 0..count {
            let lrx = rng.range_f64(0.05, 0.12) as f32 * n as f32;
            let lry = rng.range_f64(0.05, 0.12) as f32 * n as f32;
            let lx = c + rng.range_f64(-0.22, 0.22) as f32 * n as f32;
            let ly = c + rng.range_f64(-0.25, 0.25) as f32 * n as f32;
            let mut touched = false;
            for y in 0..n {
                for x in 0..n {
                    let dx = (x as f32 - lx) / lrx;
                    let dy = (y as f32 - ly) / lry;
                    if dx * dx + dy * dy <= 1.0 && labels[y * n + x] == 1 {
                        labels[y * n + x] = 4;
                        touched = true;
                    }
                }
            }
            if touched {
                lesions.push(LesionBox {
                    cx: lx,
                    cy: ly,
                    w: 2.0 * lrx,
                    h: 2.0 * lry,
                });
            }
        }
    }

    // CT image: label intensities + detector noise.
    let mut ct = Image::zeros(n, n);
    for y in 0..n {
        for x in 0..n {
            let v = match labels[y * n + x] {
                1 => CT_TISSUE,
                2 => CT_VENTRICLE,
                3 => CT_BONE,
                4 => CT_LESION,
                _ => CT_AIR,
            };
            ct.set(x, y, v + cfg.noise_sigma * rng.normal() as f32);
        }
    }
    ct.clamp01();

    // MRI: deterministic contrast remap of the *noise-free* labels plus a
    // small blur — this is the mapping the GAN has to learn.
    let mut mri = Image::zeros(n, n);
    for y in 0..n {
        for x in 0..n {
            let v = match labels[y * n + x] {
                1 => 0.62, // soft tissue bright on T2-like contrast
                2 => 0.88, // CSF very bright
                3 => 0.10, // bone dark
                4 => 0.82, // lesion hyperintense
                _ => 0.02,
            };
            mri.set(x, y, v);
        }
    }
    mri = box_blur3(&mri);

    PairedSample { ct, mri, lesions }
}

/// The deterministic CT→MRI remap applied pixel-wise (used by tests and by
/// the quickstart example to compute an "oracle" MRI from a CT without the
/// label map). Approximates the label-based construction by intensity
/// thresholds.
pub fn ct_to_mri_oracle(ct: &Image) -> Image {
    let mut out = Image::zeros(ct.width, ct.height);
    for (i, &v) in ct.data.iter().enumerate() {
        let m = if v > 0.7 {
            0.10 // bone
        } else if v > 0.41 {
            0.62 // tissue
        } else if v > 0.34 {
            0.82 // lesion band
        } else if v > 0.2 {
            0.88 // ventricle
        } else {
            0.02 // air
        };
        out.data[i] = m;
    }
    box_blur3(&out)
}

/// 3×3 box blur with replicate borders.
pub fn box_blur3(img: &Image) -> Image {
    let mut out = Image::zeros(img.width, img.height);
    for y in 0..img.height {
        for x in 0..img.width {
            let mut s = 0.0;
            for dy in -1isize..=1 {
                for dx in -1isize..=1 {
                    s += img.get_clamped(x as isize + dx, y as isize + dy);
                }
            }
            out.set(x, y, s / 9.0);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_shapes_and_ranges() {
        let cfg = PhantomConfig::default();
        let mut rng = Rng::new(1);
        let s = paired_sample(&cfg, &mut rng);
        assert_eq!(s.ct.width, 64);
        assert_eq!(s.mri.height, 64);
        let (mn, mx) = s.ct.min_max();
        assert!(mn >= 0.0 && mx <= 1.0);
        // skull ring must contain bone-bright pixels
        assert!(mx > 0.8, "expected bright skull, max={mx}");
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = PhantomConfig::default();
        let a = paired_sample(&cfg, &mut Rng::new(7));
        let b = paired_sample(&cfg, &mut Rng::new(7));
        assert_eq!(a.ct, b.ct);
        assert_eq!(a.mri, b.mri);
        assert_eq!(a.lesions.len(), b.lesions.len());
    }

    #[test]
    fn different_seeds_give_different_phantoms() {
        let cfg = PhantomConfig::default();
        let a = paired_sample(&cfg, &mut Rng::new(1));
        let b = paired_sample(&cfg, &mut Rng::new(2));
        assert_ne!(a.ct, b.ct);
    }

    #[test]
    fn lesions_appear_with_probability_one() {
        let cfg = PhantomConfig {
            lesion_prob: 1.0,
            ..PhantomConfig::default()
        };
        let mut rng = Rng::new(3);
        let mut saw = 0;
        for _ in 0..20 {
            if !paired_sample(&cfg, &mut rng).lesions.is_empty() {
                saw += 1;
            }
        }
        assert!(saw >= 18, "lesions should almost always materialize: {saw}");
    }

    #[test]
    fn no_lesions_when_prob_zero() {
        let cfg = PhantomConfig {
            lesion_prob: 0.0,
            ..PhantomConfig::default()
        };
        let mut rng = Rng::new(4);
        for _ in 0..10 {
            assert!(paired_sample(&cfg, &mut rng).lesions.is_empty());
        }
    }

    #[test]
    fn oracle_maps_bone_dark_csf_bright() {
        let cfg = PhantomConfig {
            noise_sigma: 0.0,
            ..PhantomConfig::default()
        };
        let mut rng = Rng::new(5);
        let s = paired_sample(&cfg, &mut rng);
        let oracle = ct_to_mri_oracle(&s.ct);
        // Oracle should be close to the ground-truth MRI when CT is noise-free.
        let err = crate::imaging::metrics::mse(&s.mri, &oracle).unwrap();
        assert!(err < 400.0, "oracle should approximate gt mri, mse={err}");
    }

    #[test]
    fn blur_preserves_mean_roughly() {
        let mut img = Image::zeros(16, 16);
        img.set(8, 8, 1.0);
        let blurred = box_blur3(&img);
        assert!((blurred.get(8, 8) - 1.0 / 9.0).abs() < 1e-6);
        assert!((img.mean() - blurred.mean()).abs() < 1e-3);
    }
}
