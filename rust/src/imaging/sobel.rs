//! Sobel gradient operator (Table I workload, also used by Canny).
//!
//! Rows are processed independently (parallel across threads when the
//! `parallel` feature is on) with the clamped-border handling hoisted out
//! of the per-pixel path: interior pixels read three flat row slices so the
//! inner loop autovectorizes; only the image border goes through
//! [`Image::get_clamped`]. Output is bit-identical to the scalar reference
//! ([`crate::imaging::reference::sobel`]).

use super::image::Image;
use crate::util::parallel::par_chunks2_mut;

/// Gradient magnitude and direction.
pub struct Gradient {
    pub magnitude: Image,
    /// Direction in radians, range (-pi, pi].
    pub direction: Vec<f32>,
}

/// Apply the 3×3 Sobel operator; returns magnitude (L2) and direction.
pub fn sobel(img: &Image) -> Gradient {
    let (w, h) = (img.width, img.height);
    let mut magnitude = Image::zeros(w, h);
    let mut direction = vec![0f32; w * h];
    if w > 0 && h > 0 {
        let src = &img.data;
        par_chunks2_mut(&mut magnitude.data, &mut direction, w, w, |y, mag, dir| {
            sobel_row(img, src, w, h, y, mag, dir);
        });
    }
    Gradient {
        magnitude,
        direction,
    }
}

/// One output row. Interior rows with `w >= 3` use flat slices; border rows
/// (and narrow images) fall back to the clamped per-pixel gather.
fn sobel_row(img: &Image, src: &[f32], w: usize, h: usize, y: usize, mag: &mut [f32], dir: &mut [f32]) {
    if y == 0 || y + 1 >= h || w < 3 {
        for x in 0..w {
            sobel_at_clamped(img, x, y, &mut mag[x], &mut dir[x]);
        }
        return;
    }
    let above = &src[(y - 1) * w..y * w];
    let cur = &src[y * w..(y + 1) * w];
    let below = &src[(y + 1) * w..(y + 2) * w];
    sobel_at_clamped(img, 0, y, &mut mag[0], &mut dir[0]);
    sobel_at_clamped(img, w - 1, y, &mut mag[w - 1], &mut dir[w - 1]);
    for x in 1..w - 1 {
        let gx = -above[x - 1] - 2.0 * cur[x - 1] - below[x - 1]
            + above[x + 1]
            + 2.0 * cur[x + 1]
            + below[x + 1];
        let gy = -above[x - 1] - 2.0 * above[x] - above[x + 1]
            + below[x - 1]
            + 2.0 * below[x]
            + below[x + 1];
        mag[x] = (gx * gx + gy * gy).sqrt();
        dir[x] = gy.atan2(gx);
    }
}

/// Border-pixel path, identical to the scalar reference formula.
#[inline]
fn sobel_at_clamped(img: &Image, x: usize, y: usize, mag: &mut f32, dir: &mut f32) {
    let p = |dx: isize, dy: isize| img.get_clamped(x as isize + dx, y as isize + dy);
    let gx = -p(-1, -1) - 2.0 * p(-1, 0) - p(-1, 1) + p(1, -1) + 2.0 * p(1, 0) + p(1, 1);
    let gy = -p(-1, -1) - 2.0 * p(0, -1) - p(1, -1) + p(-1, 1) + 2.0 * p(0, 1) + p(1, 1);
    *mag = (gx * gx + gy * gy).sqrt();
    *dir = gy.atan2(gx);
}

/// Sobel magnitude thresholded to a binary edge map (the "Sobel for image
/// segmentation" use in Table I).
pub fn sobel_edges(img: &Image, threshold: f32) -> Image {
    let g = sobel(img);
    let mut out = g.magnitude;
    for v in &mut out.data {
        *v = if *v >= threshold { 1.0 } else { 0.0 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vertical_step() -> Image {
        let mut img = Image::zeros(16, 16);
        for y in 0..16 {
            for x in 8..16 {
                img.set(x, y, 1.0);
            }
        }
        img
    }

    #[test]
    fn detects_vertical_edge() {
        let img = vertical_step();
        let g = sobel(&img);
        // strongest response at the step columns 7/8
        let mid = g.magnitude.get(7, 8).max(g.magnitude.get(8, 8));
        assert!(mid > 2.0, "edge response {mid}");
        // flat regions respond zero
        assert_eq!(g.magnitude.get(2, 8), 0.0);
        assert_eq!(g.magnitude.get(13, 8), 0.0);
    }

    #[test]
    fn direction_is_horizontal_gradient() {
        let img = vertical_step();
        let g = sobel(&img);
        // gradient points along +x at the edge => direction ~ 0
        let d = g.direction[8 * 16 + 7];
        assert!(d.abs() < 1e-5, "direction {d}");
    }

    #[test]
    fn constant_image_no_edges() {
        let mut img = Image::zeros(8, 8);
        for v in &mut img.data {
            *v = 0.5;
        }
        let edges = sobel_edges(&img, 0.1);
        assert!(edges.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn threshold_binarizes() {
        let img = vertical_step();
        let edges = sobel_edges(&img, 1.0);
        assert!(edges.data.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(edges.data.iter().any(|&v| v == 1.0));
    }
}
