//! Sobel gradient operator (Table I workload, also used by Canny).

use super::image::Image;

/// Gradient magnitude and direction.
pub struct Gradient {
    pub magnitude: Image,
    /// Direction in radians, range (-pi, pi].
    pub direction: Vec<f32>,
}

/// Apply the 3×3 Sobel operator; returns magnitude (L2) and direction.
pub fn sobel(img: &Image) -> Gradient {
    let (w, h) = (img.width, img.height);
    let mut magnitude = Image::zeros(w, h);
    let mut direction = vec![0f32; w * h];
    for y in 0..h {
        for x in 0..w {
            let p = |dx: isize, dy: isize| img.get_clamped(x as isize + dx, y as isize + dy);
            let gx = -p(-1, -1) - 2.0 * p(-1, 0) - p(-1, 1) + p(1, -1) + 2.0 * p(1, 0) + p(1, 1);
            let gy = -p(-1, -1) - 2.0 * p(0, -1) - p(1, -1) + p(-1, 1) + 2.0 * p(0, 1) + p(1, 1);
            magnitude.set(x, y, (gx * gx + gy * gy).sqrt());
            direction[y * w + x] = gy.atan2(gx);
        }
    }
    Gradient {
        magnitude,
        direction,
    }
}

/// Sobel magnitude thresholded to a binary edge map (the "Sobel for image
/// segmentation" use in Table I).
pub fn sobel_edges(img: &Image, threshold: f32) -> Image {
    let g = sobel(img);
    let mut out = g.magnitude;
    for v in &mut out.data {
        *v = if *v >= threshold { 1.0 } else { 0.0 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vertical_step() -> Image {
        let mut img = Image::zeros(16, 16);
        for y in 0..16 {
            for x in 8..16 {
                img.set(x, y, 1.0);
            }
        }
        img
    }

    #[test]
    fn detects_vertical_edge() {
        let img = vertical_step();
        let g = sobel(&img);
        // strongest response at the step columns 7/8
        let mid = g.magnitude.get(7, 8).max(g.magnitude.get(8, 8));
        assert!(mid > 2.0, "edge response {mid}");
        // flat regions respond zero
        assert_eq!(g.magnitude.get(2, 8), 0.0);
        assert_eq!(g.magnitude.get(13, 8), 0.0);
    }

    #[test]
    fn direction_is_horizontal_gradient() {
        let img = vertical_step();
        let g = sobel(&img);
        // gradient points along +x at the edge => direction ~ 0
        let d = g.direction[8 * 16 + 7];
        assert!(d.abs() < 1e-5, "direction {d}");
    }

    #[test]
    fn constant_image_no_edges() {
        let mut img = Image::zeros(8, 8);
        for v in &mut img.data {
            *v = 0.5;
        }
        let edges = sobel_edges(&img, 0.1);
        assert!(edges.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn threshold_binarizes() {
        let img = vertical_step();
        let edges = sobel_edges(&img, 1.0);
        assert!(edges.data.iter().all(|&v| v == 0.0 || v == 1.0));
        assert!(edges.data.iter().any(|&v| v == 1.0));
    }
}
