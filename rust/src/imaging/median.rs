//! Median filter (Table I workload).
//!
//! 3×3 and general k×k median with replicate borders. The 3×3 path uses a
//! branchless sorting network (19 compare-exchange ops — the classic
//! Smith 1996 network) because this filter is also used on the pipeline's
//! preprocessing hot path.

use super::image::Image;

#[inline(always)]
fn cswap(a: &mut f32, b: &mut f32) {
    if *a > *b {
        std::mem::swap(a, b);
    }
}

/// 3×3 median via sorting network.
pub fn median3(img: &Image) -> Image {
    let mut out = Image::zeros(img.width, img.height);
    for y in 0..img.height {
        for x in 0..img.width {
            let mut v = [0f32; 9];
            let mut k = 0;
            for dy in -1isize..=1 {
                for dx in -1isize..=1 {
                    v[k] = img.get_clamped(x as isize + dx, y as isize + dy);
                    k += 1;
                }
            }
            // 19-exchange median-of-9 network.
            let [mut v0, mut v1, mut v2, mut v3, mut v4, mut v5, mut v6, mut v7, mut v8] = v;
            cswap(&mut v1, &mut v2);
            cswap(&mut v4, &mut v5);
            cswap(&mut v7, &mut v8);
            cswap(&mut v0, &mut v1);
            cswap(&mut v3, &mut v4);
            cswap(&mut v6, &mut v7);
            cswap(&mut v1, &mut v2);
            cswap(&mut v4, &mut v5);
            cswap(&mut v7, &mut v8);
            cswap(&mut v0, &mut v3);
            cswap(&mut v5, &mut v8);
            cswap(&mut v4, &mut v7);
            cswap(&mut v3, &mut v6);
            cswap(&mut v1, &mut v4);
            cswap(&mut v2, &mut v5);
            cswap(&mut v4, &mut v7);
            cswap(&mut v4, &mut v2);
            cswap(&mut v6, &mut v4);
            cswap(&mut v4, &mut v2);
            out.set(x, y, v4);
        }
    }
    out
}

/// General k×k median (k odd) — selection by partial sort.
pub fn median_k(img: &Image, k: usize) -> Image {
    assert!(k % 2 == 1 && k >= 1, "kernel must be odd");
    let r = (k / 2) as isize;
    let mut out = Image::zeros(img.width, img.height);
    let mut buf = Vec::with_capacity(k * k);
    for y in 0..img.height {
        for x in 0..img.width {
            buf.clear();
            for dy in -r..=r {
                for dx in -r..=r {
                    buf.push(img.get_clamped(x as isize + dx, y as isize + dy));
                }
            }
            let mid = buf.len() / 2;
            buf.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
            out.set(x, y, buf[mid]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn removes_salt_noise() {
        let mut img = Image::zeros(16, 16);
        for v in &mut img.data {
            *v = 0.5;
        }
        img.set(8, 8, 1.0); // single outlier
        let filtered = median3(&img);
        assert_eq!(filtered.get(8, 8), 0.5);
    }

    #[test]
    fn constant_image_unchanged() {
        let mut img = Image::zeros(8, 8);
        for v in &mut img.data {
            *v = 0.3;
        }
        assert_eq!(median3(&img).data, img.data);
        assert_eq!(median_k(&img, 5).data, img.data);
    }

    #[test]
    fn network_matches_general_path() {
        let mut rng = Rng::new(42);
        let mut img = Image::zeros(20, 13);
        for v in &mut img.data {
            *v = rng.next_f32();
        }
        let a = median3(&img);
        let b = median_k(&img, 3);
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert_eq!(x, y);
        }
    }

    #[test]
    #[should_panic(expected = "kernel must be odd")]
    fn even_kernel_rejected() {
        median_k(&Image::zeros(4, 4), 2);
    }
}
