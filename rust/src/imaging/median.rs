//! Median filter (Table I workload).
//!
//! 3×3 and general k×k median with replicate borders. The 3×3 path uses a
//! branchless sorting network (19 compare-exchange ops — the classic
//! Smith 1996 network) over flat row slices with the clamped border split
//! out of the per-pixel path. The general `median_k` slides a window along
//! each row instead of re-sorting k² samples per pixel: Huang's 256-bin
//! running histogram when the image is exactly 8-bit-quantized (the common
//! case — anything produced by [`Image::from_u8`]), or an incrementally
//! maintained sorted window for arbitrary float data. Rows are independent,
//! so both filters parallelize across rows under the `parallel` feature;
//! all paths are bit-identical to the scalar reference
//! ([`crate::imaging::reference::median_k`]).

use super::image::Image;
use crate::util::parallel::par_chunks_mut;

#[inline(always)]
fn cswap(a: &mut f32, b: &mut f32) {
    if *a > *b {
        std::mem::swap(a, b);
    }
}

/// Median of 9 via the 19-exchange sorting network.
#[inline]
fn median9(v: [f32; 9]) -> f32 {
    let [mut v0, mut v1, mut v2, mut v3, mut v4, mut v5, mut v6, mut v7, mut v8] = v;
    cswap(&mut v1, &mut v2);
    cswap(&mut v4, &mut v5);
    cswap(&mut v7, &mut v8);
    cswap(&mut v0, &mut v1);
    cswap(&mut v3, &mut v4);
    cswap(&mut v6, &mut v7);
    cswap(&mut v1, &mut v2);
    cswap(&mut v4, &mut v5);
    cswap(&mut v7, &mut v8);
    cswap(&mut v0, &mut v3);
    cswap(&mut v5, &mut v8);
    cswap(&mut v4, &mut v7);
    cswap(&mut v3, &mut v6);
    cswap(&mut v1, &mut v4);
    cswap(&mut v2, &mut v5);
    cswap(&mut v4, &mut v7);
    cswap(&mut v4, &mut v2);
    cswap(&mut v6, &mut v4);
    cswap(&mut v4, &mut v2);
    v4
}

/// 3×3 median via sorting network.
pub fn median3(img: &Image) -> Image {
    let (w, h) = (img.width, img.height);
    let mut out = Image::zeros(w, h);
    if w == 0 || h == 0 {
        return out;
    }
    let src = &img.data;
    par_chunks_mut(&mut out.data, w, |y, row| {
        median3_row(img, src, w, h, y, row);
    });
    out
}

fn median3_row(img: &Image, src: &[f32], w: usize, h: usize, y: usize, row: &mut [f32]) {
    if y == 0 || y + 1 >= h || w < 3 {
        for (x, o) in row.iter_mut().enumerate() {
            *o = median9(gather3_clamped(img, x, y));
        }
        return;
    }
    let above = &src[(y - 1) * w..y * w];
    let cur = &src[y * w..(y + 1) * w];
    let below = &src[(y + 1) * w..(y + 2) * w];
    row[0] = median9(gather3_clamped(img, 0, y));
    row[w - 1] = median9(gather3_clamped(img, w - 1, y));
    for x in 1..w - 1 {
        row[x] = median9([
            above[x - 1],
            above[x],
            above[x + 1],
            cur[x - 1],
            cur[x],
            cur[x + 1],
            below[x - 1],
            below[x],
            below[x + 1],
        ]);
    }
}

#[inline]
fn gather3_clamped(img: &Image, x: usize, y: usize) -> [f32; 9] {
    let mut v = [0f32; 9];
    let mut k = 0;
    for dy in -1isize..=1 {
        for dx in -1isize..=1 {
            v[k] = img.get_clamped(x as isize + dx, y as isize + dy);
            k += 1;
        }
    }
    v
}

/// General k×k median (k odd) — sliding window per row instead of a
/// per-pixel partial sort.
pub fn median_k(img: &Image, k: usize) -> Image {
    assert!(k % 2 == 1 && k >= 1, "kernel must be odd");
    if k == 1 {
        return img.clone();
    }
    if k == 3 {
        return median3(img);
    }
    let (w, h) = (img.width, img.height);
    let mut out = Image::zeros(w, h);
    if w == 0 || h == 0 {
        return out;
    }
    let quantized = is_u8_quantized(&img.data);
    let src = &img.data;
    par_chunks_mut(&mut out.data, w, |y, row| {
        if quantized {
            median_row_hist(src, w, h, y, k, row);
        } else {
            median_row_sorted(src, w, h, y, k, row);
        }
    });
    out
}

/// True when every pixel round-trips through 8-bit quantization exactly —
/// then intensities form ≤256 distinct values and a 256-bin histogram
/// median is bit-exact.
fn is_u8_quantized(data: &[f32]) -> bool {
    data.iter()
        .all(|&v| (0.0..=1.0).contains(&v) && (v * 255.0).round() / 255.0 == v)
}

#[inline]
fn bin(v: f32) -> usize {
    (v * 255.0).round() as usize
}

#[inline]
fn clampi(i: isize, n: usize) -> usize {
    i.clamp(0, n as isize - 1) as usize
}

/// Huang's running-histogram median: slide the k×k window along the row,
/// updating a 256-bin histogram by one column in / one column out, and
/// re-find the median bin incrementally.
fn median_row_hist(src: &[f32], w: usize, h: usize, y: usize, k: usize, row: &mut [f32]) {
    let r = (k / 2) as isize;
    let target = (k * k / 2 + 1) as u32;
    let mut hist = [0u32; 256];
    for dy in -r..=r {
        let yy = clampi(y as isize + dy, h);
        for dx in -r..=r {
            let xx = clampi(dx, w);
            hist[bin(src[yy * w + xx])] += 1;
        }
    }
    // mdn = current median bin, below = count of samples in bins < mdn.
    let mut mdn = 0usize;
    let mut below = 0u32;
    for x in 0..w {
        if x > 0 {
            let xl = clampi(x as isize - 1 - r, w);
            let xr = clampi(x as isize + r, w);
            for dy in -r..=r {
                let yy = clampi(y as isize + dy, h);
                let bl = bin(src[yy * w + xl]);
                hist[bl] -= 1;
                if bl < mdn {
                    below -= 1;
                }
                let br = bin(src[yy * w + xr]);
                hist[br] += 1;
                if br < mdn {
                    below += 1;
                }
            }
        }
        while below >= target {
            mdn -= 1;
            below -= hist[mdn];
        }
        while below + hist[mdn] < target {
            below += hist[mdn];
            mdn += 1;
        }
        row[x] = mdn as f32 / 255.0;
    }
}

/// Arbitrary-float fallback: keep the window as a sorted vec ordered by
/// `total_cmp`, sliding by binary-search remove/insert. Still O(k) memmoves
/// per pixel instead of an O(k² log k) sort.
fn median_row_sorted(src: &[f32], w: usize, h: usize, y: usize, k: usize, row: &mut [f32]) {
    let r = (k / 2) as isize;
    let mid = k * k / 2;
    let mut win: Vec<f32> = Vec::with_capacity(k * k);
    for dy in -r..=r {
        let yy = clampi(y as isize + dy, h);
        for dx in -r..=r {
            win.push(src[yy * w + clampi(dx, w)]);
        }
    }
    win.sort_unstable_by(f32::total_cmp);
    row[0] = win[mid];
    for x in 1..w {
        let xl = clampi(x as isize - 1 - r, w);
        let xr = clampi(x as isize + r, w);
        for dy in -r..=r {
            let yy = clampi(y as isize + dy, h);
            let old = src[yy * w + xl];
            // Huang's invariant: the outgoing sample was inserted into
            // the window exactly one column earlier, and total_cmp is a
            // total order, so the search cannot miss.
            let pos = win
                .binary_search_by(|p| p.total_cmp(&old))
                // lint:allow(panic-freedom) — unreachable per the window invariant above
                .expect("sliding window must contain the outgoing sample");
            win.remove(pos);
            let new = src[yy * w + xr];
            let pos = match win.binary_search_by(|p| p.total_cmp(&new)) {
                Ok(p) | Err(p) => p,
            };
            win.insert(pos, new);
        }
        row[x] = win[mid];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::imaging::reference;
    use crate::util::rng::Rng;

    #[test]
    fn removes_salt_noise() {
        let mut img = Image::zeros(16, 16);
        for v in &mut img.data {
            *v = 0.5;
        }
        img.set(8, 8, 1.0); // single outlier
        let filtered = median3(&img);
        assert_eq!(filtered.get(8, 8), 0.5);
    }

    #[test]
    fn constant_image_unchanged() {
        let mut img = Image::zeros(8, 8);
        for v in &mut img.data {
            *v = 0.3;
        }
        assert_eq!(median3(&img).data, img.data);
        assert_eq!(median_k(&img, 5).data, img.data);
    }

    #[test]
    fn network_matches_general_path() {
        let mut rng = Rng::new(42);
        let mut img = Image::zeros(20, 13);
        for v in &mut img.data {
            *v = rng.next_f32();
        }
        let a = median3(&img);
        let b = reference::median_k(&img, 3);
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn sliding_window_matches_reference_float() {
        // Arbitrary floats take the sorted-window path.
        let mut rng = Rng::new(7);
        let mut img = Image::zeros(23, 17);
        for v in &mut img.data {
            *v = rng.next_f32();
        }
        assert!(!is_u8_quantized(&img.data));
        let a = median_k(&img, 5);
        let b = reference::median_k(&img, 5);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn histogram_path_matches_reference_quantized() {
        let mut rng = Rng::new(8);
        let bytes: Vec<u8> = (0..29 * 19).map(|_| rng.below(256) as u8).collect();
        let img = Image::from_u8(29, 19, &bytes).unwrap();
        assert!(is_u8_quantized(&img.data));
        let a = median_k(&img, 5);
        let b = reference::median_k(&img, 5);
        assert_eq!(a.data, b.data);
        let a7 = median_k(&img, 7);
        let b7 = reference::median_k(&img, 7);
        assert_eq!(a7.data, b7.data);
    }

    #[test]
    #[should_panic(expected = "kernel must be odd")]
    fn even_kernel_rejected() {
        median_k(&Image::zeros(4, 4), 2);
    }
}
