//! Imaging substrate.
//!
//! Everything the pipeline needs around pixels: an image container with PGM
//! I/O ([`image`]), the paper's accuracy metrics MSE/PSNR/SSIM
//! ([`metrics`]), the procedural paired CT/MRI phantom generator
//! ([`phantom`]) that substitutes for the paper's private paired dataset,
//! and the classical medical-imaging algorithms of Table I
//! ([`median`], [`histeq`], [`sobel`], [`canny`], [`lzw`], [`dct`]).
//!
//! The k-space acquisition front-end lives here too: a dependency-free
//! complex 2D FFT pair ([`fft`]), multi-coil k-space synthesis and
//! undersampling ([`kspace`]), and GRAPPA parallel-imaging reconstruction
//! ([`grappa`]) — the accelerated-MRI front door the pipeline's
//! `source: kspace` mode runs before the model chain.
//!
//! The kernels are the optimized (row-parallel, border-split) versions;
//! [`reference`] keeps the original scalar loops as equivalence oracles
//! for the property tests and as bench baselines.

pub mod canny;
pub mod dct;
pub mod fft;
pub mod grappa;
pub mod histeq;
pub mod image;
pub mod kspace;
pub mod lzw;
pub mod median;
pub mod metrics;
pub mod phantom;
pub mod reference;
pub mod sobel;

pub use image::Image;
