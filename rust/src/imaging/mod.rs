//! Imaging substrate.
//!
//! Everything the pipeline needs around pixels: an image container with PGM
//! I/O ([`image`]), the paper's accuracy metrics MSE/PSNR/SSIM
//! ([`metrics`]), the procedural paired CT/MRI phantom generator
//! ([`phantom`]) that substitutes for the paper's private paired dataset,
//! and the classical medical-imaging algorithms of Table I
//! ([`median`], [`histeq`], [`sobel`], [`canny`], [`lzw`], [`dct`]).

pub mod canny;
pub mod dct;
pub mod histeq;
pub mod image;
pub mod lzw;
pub mod median;
pub mod metrics;
pub mod phantom;
pub mod sobel;

pub use image::Image;
