//! Lempel–Ziv–Welch compression (Table I workload).
//!
//! Byte-oriented LZW with a growing dictionary (up to 16-bit codes) and a
//! variable-width bit packer — the codec used for lossless medical-image
//! archival in the Table I latency comparison.

use crate::error::{Error, Result};
use crate::util::hash::BuildMix64;
use std::collections::HashMap;

const MAX_CODE_BITS: u32 = 16;
pub(crate) const DICT_LIMIT: usize = 1 << MAX_CODE_BITS;

/// Pack variable-width codes into bytes (LSB-first).
pub(crate) struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub(crate) fn new() -> Self {
        BitWriter {
            out: Vec::new(),
            acc: 0,
            nbits: 0,
        }
    }

    pub(crate) fn push(&mut self, code: u32, width: u32) {
        self.acc |= (code as u64) << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    pub(crate) fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
        }
        self.out
    }
}

/// Unpack variable-width codes.
struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> Self {
        BitReader {
            data,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    fn pull(&mut self, width: u32) -> Option<u32> {
        while self.nbits < width {
            if self.pos >= self.data.len() {
                return None;
            }
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let code = (self.acc & ((1u64 << width) - 1)) as u32;
        self.acc >>= width;
        self.nbits -= width;
        Some(code)
    }
}

pub(crate) fn width_for(next_code: usize) -> u32 {
    let mut w = 9;
    while (1usize << w) < next_code + 1 && w < MAX_CODE_BITS {
        w += 1;
    }
    w
}

/// LZW-compress a byte stream.
///
/// Every dictionary string is a known prefix string extended by one byte,
/// so instead of owning byte vectors (the original cloned the running
/// sequence on *every* input byte) the dictionary keys
/// `(prefix_code << 8) | byte` packed into a `u32` — one integer probe per
/// symbol, zero allocations after the initial table reserve. Emits the
/// exact code sequence of the original, so output is bit-identical
/// (asserted against [`crate::imaging::reference::lzw_compress`]).
pub fn compress(input: &[u8]) -> Vec<u8> {
    if input.is_empty() {
        return Vec::new();
    }
    // Single-byte strings are implicit (code == byte value); only extended
    // strings live in the map.
    let mut dict: HashMap<u32, u32, BuildMix64> =
        HashMap::with_capacity_and_hasher(4096, BuildMix64::default());
    let mut next_code = 256u32;
    let mut writer = BitWriter::new();
    let mut current = input[0] as u32;
    for &b in &input[1..] {
        let key = (current << 8) | b as u32;
        match dict.get(&key) {
            Some(&code) => current = code,
            None => {
                writer.push(current, width_for(next_code as usize));
                if (next_code as usize) < DICT_LIMIT {
                    dict.insert(key, next_code);
                    next_code += 1;
                }
                current = b as u32;
            }
        }
    }
    writer.push(current, width_for(next_code as usize));
    writer.finish()
}

/// Decompress an LZW stream produced by [`compress`]. `expected_len` bounds
/// the output (guards against corrupt input).
pub fn decompress(input: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    if input.is_empty() {
        return Ok(Vec::new());
    }
    let mut dict: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
    let mut reader = BitReader::new(input);
    let mut out = Vec::with_capacity(expected_len);

    let first = reader
        .pull(width_for(dict.len()))
        .ok_or_else(|| Error::Imaging("lzw: truncated stream".into()))? as usize;
    if first >= dict.len() {
        return Err(Error::Imaging("lzw: bad first code".into()));
    }
    let mut prev = dict[first].clone();
    out.extend_from_slice(&prev);

    while out.len() < expected_len {
        // Width accounts for the entry we are *about* to add.
        let width = width_for(dict.len() + 1);
        let code = match reader.pull(width) {
            Some(c) => c as usize,
            None => break,
        };
        let entry = if code < dict.len() {
            dict[code].clone()
        } else if code == dict.len() {
            // KwKwK special case.
            let mut e = prev.clone();
            e.push(prev[0]);
            e
        } else {
            return Err(Error::Imaging(format!("lzw: code {code} out of range")));
        };
        out.extend_from_slice(&entry);
        if dict.len() < DICT_LIMIT {
            let mut new_entry = prev.clone();
            new_entry.push(entry[0]);
            dict.push(new_entry);
        }
        prev = entry;
    }
    out.truncate(expected_len);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[u8]) {
        let compressed = compress(data);
        let back = decompress(&compressed, data.len()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn empty_and_single() {
        roundtrip(&[]);
        roundtrip(&[42]);
    }

    #[test]
    fn repetitive_data_compresses() {
        let data: Vec<u8> = std::iter::repeat(b"abcabcabc".as_slice())
            .take(200)
            .flatten()
            .copied()
            .collect();
        let compressed = compress(&data);
        assert!(
            compressed.len() < data.len() / 3,
            "{} vs {}",
            compressed.len(),
            data.len()
        );
        roundtrip(&data);
    }

    #[test]
    fn kwkwk_case() {
        // Classic pattern triggering the code==dict.len() branch.
        roundtrip(b"abababababababab");
        roundtrip(b"aaaaaaaaaaaaaaaaaaaaaaa");
    }

    #[test]
    fn random_data_roundtrips() {
        let mut rng = Rng::new(33);
        for len in [1usize, 100, 1000, 5000] {
            let data: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn image_roundtrips() {
        use crate::imaging::phantom::{paired_sample, PhantomConfig};
        let cfg = PhantomConfig::default();
        let s = paired_sample(&cfg, &mut Rng::new(4));
        let bytes = s.ct.to_u8();
        let compressed = compress(&bytes);
        let back = decompress(&compressed, bytes.len()).unwrap();
        assert_eq!(back, bytes);
        // Phantoms have large flat regions -> should compress well.
        assert!(compressed.len() < bytes.len());
    }

    #[test]
    fn compress_is_bit_identical_to_reference() {
        use crate::imaging::reference;
        let mut rng = Rng::new(99);
        for len in [1usize, 17, 500, 4000] {
            // Small alphabet exercises deep dictionary growth.
            let data: Vec<u8> = (0..len).map(|_| rng.below(64) as u8).collect();
            assert_eq!(compress(&data), reference::lzw_compress(&data));
        }
        let rep: Vec<u8> = std::iter::repeat(b"medimg".as_slice())
            .take(400)
            .flatten()
            .copied()
            .collect();
        assert_eq!(compress(&rep), reference::lzw_compress(&rep));
    }

    #[test]
    fn corrupt_stream_is_error_not_panic() {
        let data = b"hello world hello world";
        let mut compressed = compress(data);
        if let Some(last) = compressed.last_mut() {
            *last = 0xFF;
        }
        compressed.extend_from_slice(&[0xFF; 8]);
        // Either an error or output not matching — must not panic.
        let _ = decompress(&compressed, data.len());
    }
}
