//! 2-D Discrete Cosine Transform (Table I workload).
//!
//! 8×8 block DCT-II with orthonormal scaling plus its inverse — the
//! JPEG-style transform used in medical image compression pipelines.

use super::image::Image;
use crate::util::parallel::par_chunks_mut;
use std::sync::OnceLock;

const N: usize = 8;

/// Scaled cosine basis with the orthonormal `alpha(k)` factor folded in:
/// `BASIS[k][n] = alpha(k) * cos(pi/N * (n + 0.5) * k)` where
/// `alpha(0) = sqrt(1/N)` and `alpha(k>0) = sqrt(2/N)`. Built once — the
/// per-block transforms previously recomputed all 64 `cos` calls (plus 16
/// `sqrt`s) on every invocation.
fn basis() -> &'static [[f32; N]; N] {
    static BASIS: OnceLock<[[f32; N]; N]> = OnceLock::new();
    BASIS.get_or_init(|| {
        let mut b = [[0f32; N]; N];
        for (k, row) in b.iter_mut().enumerate() {
            let alpha = if k == 0 {
                (1.0 / N as f32).sqrt()
            } else {
                (2.0 / N as f32).sqrt()
            };
            for (n, v) in row.iter_mut().enumerate() {
                *v = alpha
                    * (std::f32::consts::PI / N as f32 * (n as f32 + 0.5) * k as f32).cos();
            }
        }
        b
    })
}

/// Forward 8×8 DCT-II of one block (row-major 64 elements).
pub fn dct8_block(block: &[f32; 64]) -> [f32; 64] {
    let b = basis();
    let mut out = [0f32; 64];
    // rows
    let mut tmp = [0f32; 64];
    for y in 0..N {
        for k in 0..N {
            let mut s = 0.0;
            for n in 0..N {
                s += block[y * N + n] * b[k][n];
            }
            tmp[y * N + k] = s;
        }
    }
    // columns
    for x in 0..N {
        for k in 0..N {
            let mut s = 0.0;
            for n in 0..N {
                s += tmp[n * N + x] * b[k][n];
            }
            out[k * N + x] = s;
        }
    }
    out
}

/// Inverse 8×8 DCT (DCT-III with orthonormal scaling).
pub fn idct8_block(coeffs: &[f32; 64]) -> [f32; 64] {
    let b = basis();
    let mut tmp = [0f32; 64];
    // columns
    for x in 0..N {
        for n in 0..N {
            let mut s = 0.0;
            for k in 0..N {
                s += coeffs[k * N + x] * b[k][n];
            }
            tmp[n * N + x] = s;
        }
    }
    let mut out = [0f32; 64];
    // rows
    for y in 0..N {
        for n in 0..N {
            let mut s = 0.0;
            for k in 0..N {
                s += tmp[y * N + k] * b[k][n];
            }
            out[y * N + n] = s;
        }
    }
    out
}

/// Whole-image blockwise 8×8 DCT. Image dimensions must be multiples of 8.
///
/// Blocks are independent, so 8-row block bands run in parallel under the
/// `parallel` feature and each block is moved with flat row-slice copies
/// instead of per-pixel `get`/`set`. Per-block math is unchanged — output
/// is bit-identical to the scalar reference.
pub fn dct_image(img: &Image) -> Image {
    blockwise(img, dct8_block)
}

/// Whole-image blockwise inverse DCT.
pub fn idct_image(img: &Image) -> Image {
    blockwise(img, idct8_block)
}

fn blockwise(img: &Image, transform: fn(&[f32; 64]) -> [f32; 64]) -> Image {
    assert!(
        img.width % N == 0 && img.height % N == 0,
        "dims must be 8-aligned"
    );
    let w = img.width;
    let mut out = Image::zeros(w, img.height);
    let src = &img.data;
    // One chunk = one band of 8 image rows = one row of 8×8 blocks.
    par_chunks_mut(&mut out.data, w * N, |band, rows| {
        let top = band * N;
        let mut block = [0f32; 64];
        for bx in (0..w).step_by(N) {
            for y in 0..N {
                let o = (top + y) * w + bx;
                block[y * N..(y + 1) * N].copy_from_slice(&src[o..o + N]);
            }
            let coeffs = transform(&block);
            for y in 0..N {
                rows[y * w + bx..y * w + bx + N].copy_from_slice(&coeffs[y * N..(y + 1) * N]);
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn constant_block_has_only_dc() {
        let block = [0.5f32; 64];
        let coeffs = dct8_block(&block);
        // DC = 8 * 0.5 * alpha0^2-ish: orthonormal => DC = 0.5 * 8 = 4.0
        assert!((coeffs[0] - 4.0).abs() < 1e-5, "dc={}", coeffs[0]);
        for (i, &c) in coeffs.iter().enumerate().skip(1) {
            assert!(c.abs() < 1e-5, "coef {i} = {c}");
        }
    }

    #[test]
    fn roundtrip_random_block() {
        let mut rng = Rng::new(8);
        let mut block = [0f32; 64];
        for v in &mut block {
            *v = rng.next_f32();
        }
        let back = idct8_block(&dct8_block(&block));
        for (a, b) in block.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn parseval_energy_preserved() {
        let mut rng = Rng::new(9);
        let mut block = [0f32; 64];
        for v in &mut block {
            *v = rng.next_f32() - 0.5;
        }
        let coeffs = dct8_block(&block);
        let e1: f32 = block.iter().map(|v| v * v).sum();
        let e2: f32 = coeffs.iter().map(|v| v * v).sum();
        assert!((e1 - e2).abs() / e1 < 1e-4, "{e1} vs {e2}");
    }

    #[test]
    fn image_roundtrip() {
        use crate::imaging::phantom::{paired_sample, PhantomConfig};
        let s = paired_sample(&PhantomConfig::default(), &mut Rng::new(10));
        let coeffs = dct_image(&s.ct);
        let back = idct_image(&coeffs);
        for (a, b) in s.ct.data.iter().zip(back.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    #[should_panic(expected = "8-aligned")]
    fn unaligned_rejected() {
        dct_image(&Image::zeros(10, 8));
    }
}
