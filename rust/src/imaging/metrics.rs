//! Reconstruction accuracy metrics: MSE, PSNR, SSIM (paper Eqs. 1–3).
//!
//! Conventions match the paper's evaluation: images are compared in 8-bit
//! intensity space (`L = 256`), SSIM uses the standard `C1=(0.01 L)^2`,
//! `C2=(0.03 L)^2` constants computed over an 8×8 sliding window, and is
//! reported ×100 like Table II.

use super::image::Image;
use crate::error::{Error, Result};
use crate::util::parallel::par_fold;

/// Mean squared error in 8-bit intensity units (Eq. 1). Accumulated per
/// row band in parallel; band partials fold in band order, so a given
/// thread count is deterministic.
pub fn mse(original: &Image, generated: &Image) -> Result<f64> {
    check_dims(original, generated)?;
    let n = original.data.len();
    if n == 0 {
        return Ok(0.0);
    }
    const BAND: usize = 16 * 1024;
    let n_bands = n.div_ceil(BAND);
    let o = &original.data;
    let g = &generated.data;
    let sum = par_fold(
        n_bands,
        2,
        |band| {
            let lo = band.start * BAND;
            let hi = (band.end * BAND).min(n);
            o[lo..hi]
                .iter()
                .zip(&g[lo..hi])
                .map(|(&o, &g)| {
                    let d = (o as f64 - g as f64) * 255.0;
                    d * d
                })
                .sum::<f64>()
        },
        |a, b| a + b,
    )
    .unwrap_or(0.0);
    Ok(sum / n as f64)
}

/// Peak signal-to-noise ratio in dB (Eq. 2), `L = 256` intensity levels.
pub fn psnr(original: &Image, generated: &Image) -> Result<f64> {
    let m = mse(original, generated)?;
    if m == 0.0 {
        return Ok(f64::INFINITY);
    }
    Ok(10.0 * ((255.0f64 * 255.0) / m).log10())
}

/// Mean structural similarity (Eq. 3) over 8×8 windows with stride 4,
/// reported in `[0, 1]` (multiply by 100 for the paper's Table II scale).
///
/// One fused pass builds five summed-area tables (Σo, Σg, Σo², Σg², Σog in
/// `f64`), then every window's mean/variance/covariance comes from four
/// table lookups instead of re-reading 64 pixels — overlapping windows
/// (stride 4 < window 8) stop paying for their overlap. The row-prefix
/// build and the window reduction are row-parallel under the `parallel`
/// feature. Matches the scalar reference within float tolerance (~1e-5 for
/// the image sizes used here; the SAT differences cancel more digits on
/// very large images).
pub fn ssim(original: &Image, generated: &Image) -> Result<f64> {
    check_dims(original, generated)?;
    const WIN: usize = 8;
    const STRIDE: usize = 4;
    let l = 255.0f64;
    let c1 = (0.01 * l) * (0.01 * l);
    let c2 = (0.03 * l) * (0.03 * l);
    let (w, h) = (original.width, original.height);
    if w < WIN || h < WIN {
        return Err(Error::Imaging(format!(
            "image {w}x{h} smaller than ssim window {WIN}"
        )));
    }

    // Pass 1 (row-parallel): per-row running sums into row y+1 of the SAT.
    // Cell layout is [Σo, Σg, Σo², Σg², Σog] so one window probe reads
    // contiguous memory.
    let stride = w + 1;
    let mut sat = vec![[0f64; 5]; stride * (h + 1)];
    {
        let o = &original.data;
        let g = &generated.data;
        crate::util::parallel::par_chunks_mut(&mut sat[stride..], stride, |y, row| {
            let mut run = [0f64; 5];
            for x in 0..w {
                let ov = o[y * w + x] as f64 * 255.0;
                let gv = g[y * w + x] as f64 * 255.0;
                run[0] += ov;
                run[1] += gv;
                run[2] += ov * ov;
                run[3] += gv * gv;
                run[4] += ov * gv;
                row[x + 1] = run;
            }
        });
    }
    // Pass 2 (serial, vectorizable adds): accumulate rows downward.
    for y in 2..=h {
        let (prev, cur) = sat.split_at_mut(y * stride);
        let prev = &prev[(y - 1) * stride..];
        for (c, p) in cur[..stride].iter_mut().zip(prev) {
            for j in 0..5 {
                c[j] += p[j];
            }
        }
    }

    // Window reduction, parallel across window rows.
    let wins_x = (w - WIN) / STRIDE + 1;
    let wins_y = (h - WIN) / STRIDE + 1;
    let n = (WIN * WIN) as f64;
    let sat = &sat;
    let total = par_fold(
        wins_y,
        4,
        |band| {
            let mut t = 0.0f64;
            for wy in band {
                let y0 = wy * STRIDE;
                let y1 = y0 + WIN;
                for wx in 0..wins_x {
                    let x0 = wx * STRIDE;
                    let x1 = x0 + WIN;
                    let a = &sat[y0 * stride + x0];
                    let b = &sat[y0 * stride + x1];
                    let c = &sat[y1 * stride + x0];
                    let d = &sat[y1 * stride + x1];
                    let sum = |j: usize| d[j] - b[j] - c[j] + a[j];
                    let mo = sum(0) / n;
                    let mg = sum(1) / n;
                    let vo = (sum(2) / n - mo * mo).max(0.0);
                    let vg = (sum(3) / n - mg * mg).max(0.0);
                    let cov = sum(4) / n - mo * mg;
                    t += ((2.0 * mo * mg + c1) * (2.0 * cov + c2))
                        / ((mo * mo + mg * mg + c1) * (vo + vg + c2));
                }
            }
            t
        },
        |a, b| a + b,
    )
    .unwrap_or(0.0);
    Ok(total / (wins_x * wins_y) as f64)
}

/// All three metrics at once (the Table II row for one model).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fidelity {
    pub mse: f64,
    pub psnr: f64,
    /// SSIM ×100 as reported in the paper.
    pub ssim_pct: f64,
}

pub fn fidelity(original: &Image, generated: &Image) -> Result<Fidelity> {
    Ok(Fidelity {
        mse: mse(original, generated)?,
        psnr: psnr(original, generated)?,
        ssim_pct: ssim(original, generated)? * 100.0,
    })
}

fn check_dims(a: &Image, b: &Image) -> Result<()> {
    if a.width != b.width || a.height != b.height {
        return Err(Error::Imaging(format!(
            "dimension mismatch: {}x{} vs {}x{}",
            a.width, a.height, b.width, b.height
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn noisy_copy(img: &Image, sigma: f32, seed: u64) -> Image {
        let mut rng = Rng::new(seed);
        let mut out = img.clone();
        for v in &mut out.data {
            *v = (*v + sigma * rng.normal() as f32).clamp(0.0, 1.0);
        }
        out
    }

    fn test_image() -> Image {
        let mut img = Image::zeros(32, 32);
        for y in 0..32 {
            for x in 0..32 {
                img.set(x, y, ((x + y) as f32 / 62.0).clamp(0.0, 1.0));
            }
        }
        img
    }

    #[test]
    fn identical_images_are_perfect() {
        let img = test_image();
        assert_eq!(mse(&img, &img).unwrap(), 0.0);
        assert_eq!(psnr(&img, &img).unwrap(), f64::INFINITY);
        assert!((ssim(&img, &img).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_degrade_with_noise() {
        let img = test_image();
        let slightly = noisy_copy(&img, 0.02, 1);
        let very = noisy_copy(&img, 0.2, 2);
        assert!(mse(&img, &slightly).unwrap() < mse(&img, &very).unwrap());
        assert!(psnr(&img, &slightly).unwrap() > psnr(&img, &very).unwrap());
        assert!(ssim(&img, &slightly).unwrap() > ssim(&img, &very).unwrap());
    }

    #[test]
    fn mse_known_value() {
        let a = Image::from_data(8, 8, vec![0.0; 64]).unwrap();
        let b = Image::from_data(8, 8, vec![1.0; 64]).unwrap();
        // every pixel differs by 255 -> mse = 255^2
        assert!((mse(&a, &b).unwrap() - 255.0 * 255.0).abs() < 1e-9);
        // psnr of max error = 0 dB
        assert!((psnr(&a, &b).unwrap() - 0.0).abs() < 1e-9);
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let a = Image::zeros(8, 8);
        let b = Image::zeros(8, 9);
        assert!(mse(&a, &b).is_err());
        assert!(ssim(&a, &b).is_err());
    }

    #[test]
    fn ssim_window_guard() {
        let a = Image::zeros(4, 4);
        assert!(ssim(&a, &a).is_err());
    }

    #[test]
    fn fidelity_bundles_all() {
        let img = test_image();
        let noisy = noisy_copy(&img, 0.05, 3);
        let f = fidelity(&img, &noisy).unwrap();
        assert!(f.mse > 0.0);
        assert!(f.psnr > 10.0 && f.psnr < 60.0);
        assert!(f.ssim_pct > 10.0 && f.ssim_pct < 100.0);
    }
}
