//! Complex-valued 2D FFT for the k-space acquisition front-end.
//!
//! Dependency-free radix-2 decimation-in-time over split complex planes
//! (`re`/`im`, row-major). A [`FftPlan`] precomputes the bit-reversal
//! permutation and the twiddle tables once (angles evaluated in f64, cast
//! to f32); [`Fft2`] applies it row-wise with an in-place square transpose
//! between passes. The row pass band-splits over rows through
//! [`crate::util::parallel::par_chunks2_mut`] with exactly one chunk per
//! row, so the per-row butterfly order — and therefore the f32 result —
//! is identical at any thread count and bit-exact against the scalar
//! oracle in [`crate::imaging::reference`].

// Per-frame acquisition path: a panic here kills the source thread.
#![deny(clippy::unwrap_used)]

use crate::error::{Error, Result};
use crate::util::parallel::par_chunks2_mut;

/// Precomputed length-`n` radix-2 plan: bit-reversal permutation plus
/// half-length twiddle tables (forward sign; the inverse conjugates).
#[derive(Debug, Clone)]
pub struct FftPlan {
    n: usize,
    rev: Vec<u32>,
    tw_re: Vec<f32>,
    tw_im: Vec<f32>,
}

impl FftPlan {
    /// Plan a length-`n` transform; `n` must be a power of two ≥ 2.
    pub fn new(n: usize) -> Result<FftPlan> {
        if n < 2 || !n.is_power_of_two() {
            return Err(Error::Imaging(format!(
                "fft length {n} is not a power of two >= 2"
            )));
        }
        let bits = n.trailing_zeros();
        let rev = (0..n as u32)
            .map(|i| i.reverse_bits() >> (32 - bits))
            .collect();
        let half = n / 2;
        let mut tw_re = vec![0.0f32; half];
        let mut tw_im = vec![0.0f32; half];
        for (k, (re, im)) in tw_re.iter_mut().zip(tw_im.iter_mut()).enumerate() {
            let ang = -2.0 * std::f64::consts::PI * k as f64 / n as f64;
            *re = ang.cos() as f32;
            *im = ang.sin() as f32;
        }
        Ok(FftPlan {
            n,
            rev,
            tw_re,
            tw_im,
        })
    }

    /// The planned transform length.
    pub fn size(&self) -> usize {
        self.n
    }

    /// One in-place 1D transform over a length-`n` line. `inverse`
    /// conjugates the twiddles and applies the 1/n scale.
    pub fn transform(&self, re: &mut [f32], im: &mut [f32], inverse: bool) {
        let n = self.n;
        assert!(re.len() == n && im.len() == n, "fft line length mismatch");
        for (i, &r) in self.rev.iter().enumerate() {
            let j = r as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        let mut len = 2usize;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            let mut base = 0usize;
            while base < n {
                let mut k = 0usize;
                for off in 0..half {
                    let wr = self.tw_re[k];
                    let wi = if inverse { -self.tw_im[k] } else { self.tw_im[k] };
                    let a = base + off;
                    let b = a + half;
                    let xr = re[b] * wr - im[b] * wi;
                    let xi = re[b] * wi + im[b] * wr;
                    re[b] = re[a] - xr;
                    im[b] = im[a] - xi;
                    re[a] += xr;
                    im[a] += xi;
                    k += step;
                }
                base += len;
            }
            len *= 2;
        }
        if inverse {
            let s = 1.0 / n as f32;
            for v in re.iter_mut() {
                *v *= s;
            }
            for v in im.iter_mut() {
                *v *= s;
            }
        }
    }
}

/// Square 2D FFT/iFFT pair over split complex planes (length `n*n`).
#[derive(Debug, Clone)]
pub struct Fft2 {
    plan: FftPlan,
    n: usize,
}

impl Fft2 {
    /// Plan for `n`×`n` planes; `n` must be a power of two ≥ 2.
    pub fn new(n: usize) -> Result<Fft2> {
        Ok(Fft2 {
            plan: FftPlan::new(n)?,
            n,
        })
    }

    /// Plane side length.
    pub fn size(&self) -> usize {
        self.n
    }

    fn check(&self, re: &[f32], im: &[f32]) -> Result<()> {
        let want = self.n * self.n;
        if re.len() != want || im.len() != want {
            return Err(Error::Imaging(format!(
                "fft2 plane lengths {}/{} != {want}",
                re.len(),
                im.len()
            )));
        }
        Ok(())
    }

    /// Forward 2D FFT in place: rows, transpose, rows, transpose back.
    /// Per-frame: validation + delegation only (loops live in
    /// [`row_pass`]/[`transpose_square`]).
    pub fn fft2(&self, re: &mut [f32], im: &mut [f32]) -> Result<()> {
        self.check(re, im)?;
        row_pass(&self.plan, re, im, false);
        transpose_square(self.n, re);
        transpose_square(self.n, im);
        row_pass(&self.plan, re, im, false);
        transpose_square(self.n, re);
        transpose_square(self.n, im);
        Ok(())
    }

    /// Inverse 2D FFT in place; scales by 1/n per axis.
    pub fn ifft2(&self, re: &mut [f32], im: &mut [f32]) -> Result<()> {
        self.check(re, im)?;
        row_pass(&self.plan, re, im, true);
        transpose_square(self.n, re);
        transpose_square(self.n, im);
        row_pass(&self.plan, re, im, true);
        transpose_square(self.n, re);
        transpose_square(self.n, im);
        Ok(())
    }
}

/// Row-wise 1D transforms over both planes, one parallel chunk per row:
/// every row's butterflies run serially inside its chunk, so the result
/// is bit-identical at any thread count.
fn row_pass(plan: &FftPlan, re: &mut [f32], im: &mut [f32], inverse: bool) {
    let n = plan.size();
    par_chunks2_mut(re, im, n, n, |_row, rr, ir| {
        plan.transform(rr, ir, inverse);
    });
}

/// In-place square transpose. Serial: the O(n²) swap pass is tiny next to
/// the O(n² log n) butterfly work on either side of it.
fn transpose_square(n: usize, a: &mut [f32]) {
    for y in 0..n {
        for x in (y + 1)..n {
            a.swap(y * n + x, x * n + y);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max)
    }

    #[test]
    fn rejects_non_power_of_two_and_bad_plane_lengths() {
        assert!(Fft2::new(0).is_err());
        assert!(Fft2::new(1).is_err());
        assert!(Fft2::new(48).is_err());
        let f = Fft2::new(8).unwrap();
        let mut re = vec![0.0f32; 63];
        let mut im = vec![0.0f32; 63];
        assert!(f.fft2(&mut re, &mut im).is_err());
    }

    #[test]
    fn impulse_transforms_to_flat_spectrum() {
        let n = 16usize;
        let f = Fft2::new(n).unwrap();
        let mut re = vec![0.0f32; n * n];
        let mut im = vec![0.0f32; n * n];
        re[0] = 1.0;
        f.fft2(&mut re, &mut im).unwrap();
        for (&r, &i) in re.iter().zip(im.iter()) {
            assert!((r - 1.0).abs() < 1e-5 && i.abs() < 1e-5);
        }
    }

    #[test]
    fn dc_plane_concentrates_at_zero_frequency() {
        let n = 8usize;
        let f = Fft2::new(n).unwrap();
        let mut re = vec![0.5f32; n * n];
        let mut im = vec![0.0f32; n * n];
        f.fft2(&mut re, &mut im).unwrap();
        assert!((re[0] - 0.5 * (n * n) as f32).abs() < 1e-3);
        let off_dc: f32 = re.iter().skip(1).map(|v| v.abs()).sum();
        assert!(off_dc < 1e-3, "energy leaked off DC: {off_dc}");
    }

    #[test]
    fn fft_ifft_round_trip_is_tight() {
        let n = 32usize;
        let f = Fft2::new(n).unwrap();
        let src: Vec<f32> = (0..n * n)
            .map(|i| ((i as f32 * 0.37).sin() * 0.5 + 0.5) * 0.9)
            .collect();
        let mut re = src.clone();
        let mut im = vec![0.0f32; n * n];
        f.fft2(&mut re, &mut im).unwrap();
        f.ifft2(&mut re, &mut im).unwrap();
        assert!(max_abs_diff(&re, &src) < 1e-4);
        assert!(im.iter().all(|v| v.abs() < 1e-4));
    }
}
